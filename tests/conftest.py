import gc
import os
import sys

import pytest

# tests must see exactly 1 device (the dry-run sets its own flags in-process)
os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_mmaps():
    """Release compiled executables at module boundaries.

    Every XLA CPU executable pins dozens of small LLVM JIT mappings and
    jax keeps them alive in its jit caches forever; across the full suite
    the process crosses ``vm.max_map_count`` (65530 default) and mmap
    starts failing with ENOMEM -- which surfaces as LLVM "Cannot allocate
    memory" errors and a segfault, not a clean Python error. Clearing
    per module keeps the map count bounded by the fattest single module;
    the persistent compilation cache (repro.xla_cache) turns the
    resulting recompiles into cheap disk deserializes."""
    yield
    import jax
    jax.clear_caches()
    gc.collect()


def _map_count() -> int:
    try:
        with open(f"/proc/{os.getpid()}/maps", "rb") as f:
            return sum(1 for _ in f)
    except OSError:          # non-linux: no visibility, rely on the
        return 0             # module-boundary clear alone


@pytest.fixture(autouse=True)
def _bound_jit_mmaps_within_module():
    """Emergency valve for a single FAT module: the module-boundary clear
    above can't help when one module alone compiles enough programs to
    cross the map ceiling mid-module (test_serving_equivalence grew past
    it once the spec-decode axis landed). Checking /proc maps per test is
    ~free; clearing only near the ceiling keeps warm jit caches for the
    99% case."""
    yield
    if _map_count() > 45_000:
        import jax
        jax.clear_caches()
        gc.collect()
