"""Serving engine: admission control (no trial-and-error), page accounting,
context-switch exactness (paper Table 7), batch-composition independence."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import PageAllocator, ServingEngine


@pytest.fixture(scope="module")
def engine():
    return ServingEngine(get_config("tiny"), max_slots=4, max_len=128,
                         rng_seed=0)


def _drain(eng, slot):
    while not eng.is_done(slot):
        eng.step()
    out = eng.result(slot)
    eng.free(slot)
    return out


class TestPaging:
    def test_reserve_grow_release(self):
        pa = PageAllocator(num_pages=10, page_size=16)
        assert pa.reserve("s0", 40)          # 3 pages
        assert pa.used_pages == 3
        assert pa.grow("s0", 70)             # -> 5 pages
        assert pa.held("s0") == 5
        assert not pa.reserve("s1", 100)     # 7 > 5 free
        assert pa.failed_reservations == 1
        assert pa.release("s0") == 5
        assert pa.free_pages == 10

    def test_admission_never_overcommits(self):
        pa = PageAllocator(num_pages=4, page_size=16)
        assert pa.can_admit(64)
        assert not pa.can_admit(65)


class TestEngine:
    def test_generate_and_free(self, engine):
        slot = engine.add_sequence(np.arange(1, 9), max_new=8)
        out = _drain(engine, slot)
        assert len(out) == 8
        assert engine.free_slot_count() == engine.max_slots

    def test_admission_rejects_when_full(self, engine):
        slots = [engine.add_sequence(np.arange(1, 5), max_new=4)
                 for _ in range(engine.max_slots)]
        with pytest.raises(RuntimeError):
            engine.add_sequence(np.arange(1, 5), max_new=4)
        for s in slots:
            _drain(engine, s)

    def test_context_too_long_rejected(self, engine):
        with pytest.raises(RuntimeError):
            engine.add_sequence(np.arange(1, 100), max_new=100)

    def test_batch_composition_independence(self):
        """A sequence's output must not depend on what else is in the batch."""
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=4, max_len=128, rng_seed=0)
        prompt = np.arange(1, 9)
        alone = _drain(eng, eng.add_sequence(prompt, max_new=10))
        # same prompt co-batched with others
        others = [eng.add_sequence(np.arange(2, 20, 2), max_new=10),
                  eng.add_sequence(np.array([9, 8, 7]), max_new=10)]
        mine = eng.add_sequence(prompt, max_new=10)
        while not eng.is_done(mine):
            eng.step()
        together = eng.result(mine)
        assert alone == together

    @pytest.mark.parametrize("kind", ["logits", "text"])
    @pytest.mark.parametrize("temperature", [0.0, 0.7])
    def test_context_switch_exact(self, kind, temperature):
        """Paper Table 7: outputs with and without a mid-generation context
        switch must match exactly (BLEU/BERTScore 1.0 <=> identical ids)."""
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=4, max_len=128,
                            temperature=temperature, rng_seed=1)
        prompt = np.arange(1, 9)
        ref = _drain(eng, eng.add_sequence(prompt, max_new=12))

        slot = eng.add_sequence(prompt, max_new=12)
        for _ in range(5):
            eng.step()
        snap = eng.snapshot(slot, kind=kind)
        # interleave unrelated work
        other = eng.add_sequence(np.arange(5, 50, 5), max_new=6)
        _drain(eng, other)
        slot = eng.restore(snap)
        out = _drain(eng, slot)
        assert out == ref, (kind, temperature)

    def test_snapshot_accounting(self):
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=2, max_len=128, rng_seed=2)
        slot = eng.add_sequence(np.arange(1, 9), max_new=8)
        used_before = eng.pager.used_pages
        assert used_before > 0
        eng.step()
        snap = eng.snapshot(slot)
        assert eng.pager.used_pages == 0          # pages released on preempt
        assert snap.nbytes() > 0                  # host pool now holds state
        slot = eng.restore(snap)
        assert eng.pager.used_pages > 0
        _drain(eng, slot)

    def test_failed_load_probe_counts(self):
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=1, max_len=64, rng_seed=3)
        s = eng.add_sequence(np.arange(1, 5), max_new=4)
        eng.probe_failed_load(np.arange(1, 9))
        assert eng.stats["failed_loads"] == 1
        _drain(eng, s)


class TestPrefixCache:
    """Pool-wide prompt prefix caching: restore-then-extend instead of
    re-prefill, bit-exact with the cache on and off."""

    def _mk(self, cache, params=None):
        from repro.serving import PrefixCache
        return ServingEngine(get_config("tiny"), max_slots=4, max_len=256,
                             rng_seed=0, params=params,
                             prefix_cache=PrefixCache() if cache else None)

    def test_exact_hit_skips_prefill(self):
        eng = self._mk(cache=True)
        prompt = np.arange(1, 33)
        first = _drain(eng, eng.add_sequence(prompt, max_new=6))
        assert eng.stats["prefills"] == 1
        second = _drain(eng, eng.add_sequence(prompt, max_new=6))
        assert eng.stats["prefills"] == 1          # prefill skipped entirely
        assert eng.stats["prefix_hits"] == 1
        assert first == second                     # and tokens identical

    def test_multi_turn_extend_bit_exact(self):
        """A grown conversation (prev prompt + prev generation + new turn)
        must decode-extend from the cached prefix and emit exactly the tokens
        the cache-off engine produces."""
        ref = self._mk(cache=False)
        eng = self._mk(cache=True, params=ref.params)

        def conversation(e):
            prompt = list(range(1, 33))
            outs = []
            for turn in range(3):
                slot = e.add_sequence(np.asarray(prompt, np.int32), max_new=6)
                while not e.is_done(slot):
                    e.step()
                g = e.result(slot)
                e.harvest_prefix(slot)
                e.free(slot)
                outs.append(list(g))
                prompt = prompt + g + [40 + turn, 50 + turn]  # new user turn
            return outs

        assert conversation(ref) == conversation(eng)
        assert ref.stats["prefills"] == 3
        assert eng.stats["prefills"] == 1          # turns 2,3 extended
        assert eng.stats["prefix_hits"] == 2
        assert eng.stats["prefix_saved_tokens"] > 0

    def test_lru_budget_eviction(self):
        from repro.serving import PrefixCache
        from repro.serving.engine import ContextSnapshot

        def snap(tokens, nbytes):
            s = ContextSnapshot(kind="prefix",
                                prompt=np.asarray(tokens, np.int32),
                                generated=[], seq_len=len(tokens),
                                state=[np.zeros(nbytes, np.uint8)])
            return s

        pc = PrefixCache(budget_bytes=4096, max_entries=8, min_tokens=4)
        assert pc.insert(snap(range(8), 1500))
        assert pc.insert(snap(range(100, 108), 1500))
        assert pc.insert(snap(range(200, 208), 1500))   # evicts the oldest
        assert pc.stats["evictions"] >= 1
        assert pc.lookup(list(range(8)) + [9]) is None  # evicted
        assert pc.lookup(list(range(200, 208)) + [9]) is not None
        assert not pc.insert(snap(range(300, 303), 64))  # below min_tokens

    def test_longest_prefix_wins(self):
        from repro.serving import PrefixCache
        from repro.serving.engine import ContextSnapshot
        pc = PrefixCache(min_tokens=2)
        base = list(range(10, 30))
        for n in (4, 8, 16):
            pc.insert(ContextSnapshot(kind="prefix",
                                      prompt=np.asarray(base[:n], np.int32),
                                      generated=[], seq_len=n, state=[]))
        hit = pc.lookup(np.asarray(base, np.int32))
        assert hit is not None and hit.seq_len == 16

    def test_pool_shares_prefix_across_cores(self):
        """A prefix prefilled on one core must be a hit on any core: the
        kernel gives every core the same PrefixCache instance."""
        from repro.core import AIOSKernel
        from repro.sdk.query import LLMQuery
        k = AIOSKernel(arch="tiny", scheduler="batched", num_cores=2,
                       engine_kw={"max_slots": 2, "max_len": 256})
        assert (k.pool.cores[0].engine.prefix_cache
                is k.pool.cores[1].engine.prefix_cache)
        with k:
            prompt = list(range(1, 33))
            outs = []
            for i in range(3):                      # sequential resubmissions
                sc = LLMQuery(prompt=prompt,
                              max_new_tokens=6).to_syscall(f"share{i}")
                k.submit(sc)
                outs.append(sc.join(timeout=300)["tokens"])
            m = k.metrics()
        assert outs[0] == outs[1] == outs[2]
        assert m["prefix_cache"]["hits"] >= 2
        total_prefills = sum(e["prefills"] for e in m["engine"])
        assert total_prefills <= 1                  # only the first admission
