"""Serving engine: admission control (no trial-and-error), page accounting,
context-switch exactness (paper Table 7), batch-composition independence."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import PageAllocator, ServingEngine


@pytest.fixture(scope="module")
def engine():
    return ServingEngine(get_config("tiny"), max_slots=4, max_len=128,
                         rng_seed=0)


def _drain(eng, slot):
    while not eng.is_done(slot):
        eng.step()
    out = eng.result(slot)
    eng.free(slot)
    return out


class TestPaging:
    def test_reserve_grow_release(self):
        pa = PageAllocator(num_pages=10, page_size=16)
        assert pa.reserve("s0", 40)          # 3 pages
        assert pa.used_pages == 3
        assert pa.grow("s0", 70)             # -> 5 pages
        assert pa.held("s0") == 5
        assert not pa.reserve("s1", 100)     # 7 > 5 free
        assert pa.failed_reservations == 1
        assert pa.release("s0") == 5
        assert pa.free_pages == 10

    def test_admission_never_overcommits(self):
        pa = PageAllocator(num_pages=4, page_size=16)
        assert pa.can_admit(64)
        assert not pa.can_admit(65)


class TestEngine:
    def test_generate_and_free(self, engine):
        slot = engine.add_sequence(np.arange(1, 9), max_new=8)
        out = _drain(engine, slot)
        assert len(out) == 8
        assert engine.free_slot_count() == engine.max_slots

    def test_admission_rejects_when_full(self, engine):
        slots = [engine.add_sequence(np.arange(1, 5), max_new=4)
                 for _ in range(engine.max_slots)]
        with pytest.raises(RuntimeError):
            engine.add_sequence(np.arange(1, 5), max_new=4)
        for s in slots:
            _drain(engine, s)

    def test_context_too_long_rejected(self, engine):
        with pytest.raises(RuntimeError):
            engine.add_sequence(np.arange(1, 100), max_new=100)

    def test_batch_composition_independence(self):
        """A sequence's output must not depend on what else is in the batch."""
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=4, max_len=128, rng_seed=0)
        prompt = np.arange(1, 9)
        alone = _drain(eng, eng.add_sequence(prompt, max_new=10))
        # same prompt co-batched with others
        others = [eng.add_sequence(np.arange(2, 20, 2), max_new=10),
                  eng.add_sequence(np.array([9, 8, 7]), max_new=10)]
        mine = eng.add_sequence(prompt, max_new=10)
        while not eng.is_done(mine):
            eng.step()
        together = eng.result(mine)
        assert alone == together

    @pytest.mark.parametrize("kind", ["logits", "text"])
    @pytest.mark.parametrize("temperature", [0.0, 0.7])
    def test_context_switch_exact(self, kind, temperature):
        """Paper Table 7: outputs with and without a mid-generation context
        switch must match exactly (BLEU/BERTScore 1.0 <=> identical ids)."""
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=4, max_len=128,
                            temperature=temperature, rng_seed=1)
        prompt = np.arange(1, 9)
        ref = _drain(eng, eng.add_sequence(prompt, max_new=12))

        slot = eng.add_sequence(prompt, max_new=12)
        for _ in range(5):
            eng.step()
        snap = eng.snapshot(slot, kind=kind)
        # interleave unrelated work
        other = eng.add_sequence(np.arange(5, 50, 5), max_new=6)
        _drain(eng, other)
        slot = eng.restore(snap)
        out = _drain(eng, slot)
        assert out == ref, (kind, temperature)

    def test_snapshot_accounting(self):
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=2, max_len=128, rng_seed=2)
        slot = eng.add_sequence(np.arange(1, 9), max_new=8)
        used_before = eng.pager.used_pages
        assert used_before > 0
        eng.step()
        snap = eng.snapshot(slot)
        assert eng.pager.used_pages == 0          # pages released on preempt
        assert snap.nbytes() > 0                  # host pool now holds state
        slot = eng.restore(snap)
        assert eng.pager.used_pages > 0
        _drain(eng, slot)

    def test_failed_load_probe_counts(self):
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=1, max_len=64, rng_seed=3)
        s = eng.add_sequence(np.arange(1, 5), max_new=4)
        eng.probe_failed_load(np.arange(1, 9))
        assert eng.stats["failed_loads"] == 1
        _drain(eng, s)


class TestChunkedPrefill:
    """Batched chunked prefill (burst admission) must be bit-exact with the
    legacy one-sequence-per-XLA-call path, with the prefix cache on or off."""

    def _mk(self, *, serial=False, cache=False, params=None, pc=None,
            max_len=256):
        from repro.serving import PrefixCache
        if cache and pc is None:
            pc = PrefixCache()
        return ServingEngine(get_config("tiny"), max_slots=8, max_len=max_len,
                             rng_seed=0, params=params, serial_prefill=serial,
                             prefix_cache=pc)

    def _prompts(self):
        rng = np.random.default_rng(7)
        return [rng.integers(1, 500, n).astype(np.int32)
                for n in (8, 33, 100, 230, 64, 17)]

    def _drain_all(self, eng, slots):
        while any(not eng.is_done(s) for s in slots):
            eng.step()
        outs = [eng.result(s) for s in slots]
        for s in slots:
            eng.free(s)
        return outs

    @pytest.mark.parametrize("cache", [False, True])
    @pytest.mark.parametrize("temperature", [0.0, 0.7])
    def test_burst_matches_serial(self, cache, temperature):
        from repro.serving import PrefixCache
        cfg = get_config("tiny")
        ref = ServingEngine(cfg, max_slots=8, max_len=256, rng_seed=0,
                            temperature=temperature, serial_prefill=True,
                            prefix_cache=PrefixCache() if cache else None)
        eng = ServingEngine(cfg, max_slots=8, max_len=256, rng_seed=0,
                            temperature=temperature, params=ref.params,
                            prefix_cache=PrefixCache() if cache else None)
        prompts = self._prompts()
        ref_out = [self._drain_all(ref, [ref.add_sequence(p, max_new=10)])[0]
                   for p in prompts]
        slots = eng.add_sequences([dict(prompt=p, max_new=10)
                                   for p in prompts])
        assert self._drain_all(eng, slots) == ref_out
        assert eng.stats["prefill_bursts"] == 1
        # the whole burst fits one 256-token chunk dispatch
        assert eng.stats["prefill_chunks"] == 1
        assert eng.stats["prefills"] == len(prompts)

    def test_single_admissions_match_serial(self):
        """One-at-a-time admissions stay exact both ways: the eager burst-of-
        one fast path (delegates to serial prefill) and a forced chunked
        single (eager=False + manual drain, the scheduler's shape)."""
        ref = self._mk(serial=True)
        eng = self._mk(params=ref.params)
        for p in self._prompts():
            a = self._drain_all(ref, [ref.add_sequence(p, max_new=10)])[0]
            b = self._drain_all(eng, [eng.add_sequence(p, max_new=10)])[0]
            slot = eng.add_sequence(p, max_new=10, eager=False)
            assert eng.is_prefilling(slot)
            while eng.prefill_pending():
                eng.prefill_step()
            c = self._drain_all(eng, [slot])[0]
            assert a == b == c

    def test_prefill_interleaves_without_disturbing_decode(self):
        """Chunked prefill writes into the shared decode cache; rows that are
        decoding (or idle) must be preserved bit-for-bit across interleaved
        chunk dispatches -- and vice versa for half-prefilled rows across
        decode steps."""
        ref = self._mk(serial=True)
        eng = self._mk(params=ref.params)
        prompt = np.arange(1, 9)
        expect = self._drain_all(ref, [ref.add_sequence(prompt, max_new=12)])[0]

        slot = eng.add_sequence(prompt, max_new=12)
        for _ in range(3):
            eng.step()
        rng = np.random.default_rng(3)
        late = eng.add_sequences(
            [dict(prompt=rng.integers(1, 500, 200).astype(np.int32),
                  max_new=4),
             dict(prompt=rng.integers(1, 500, 90).astype(np.int32),
                  max_new=4)], eager=False)
        while eng.prefill_pending():
            eng.prefill_step()     # one chunk ...
            eng.step()             # ... then a decode quantum, interleaved
        assert self._drain_all(eng, [slot] + late)[0] == expect

    @pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-2b"])
    def test_stateful_model_slot_reuse_is_clean(self, arch):
        """Chunked prefill resumes recurrent state from the cache row, so a
        reused slot must be reset before a fresh prompt's first chunk -- a
        previous occupant's wkv/RG-LRU carries must not leak in."""
        cfg = get_config(arch, smoke=True)
        eng = ServingEngine(cfg, max_slots=2, max_len=128, rng_seed=0)
        assert eng.model.stateful_prefill
        prompt_b = np.arange(5, 45)
        # reference: B admitted on a pristine slot
        ref = ServingEngine(cfg, max_slots=2, max_len=128, rng_seed=0,
                            params=eng.params)
        expect = self._drain_all(ref, [ref.add_sequence(prompt_b, max_new=6)])

        def admit_chunked(e, prompt):
            # eager=False forces the chunked path (an eager burst of one
            # takes the serial fast path, which resets state trivially)
            slot = e.add_sequence(prompt, max_new=6, eager=False)
            while e.prefill_pending():
                e.prefill_step()
            return slot

        # dirty the slot with a different sequence first, then reuse it
        self._drain_all(eng, [admit_chunked(eng, np.arange(100, 160))])
        got = self._drain_all(eng, [admit_chunked(eng, prompt_b)])
        assert got == expect

    @pytest.mark.parametrize("eager", [True, False])
    def test_restore_text_reprefills_chunked(self, eager):
        """Text-kind restore re-prefills through the chunked queue; with
        eager=False it only enqueues, so a worker can interleave the
        re-prefill with decode instead of stalling on it."""
        eng = self._mk()
        slot = eng.add_sequence(np.arange(1, 40), max_new=12)
        ref = self._drain_all(eng, [slot])[0]
        slot = eng.add_sequence(np.arange(1, 40), max_new=12)
        for _ in range(5):
            eng.step()
        snap = eng.snapshot(slot, kind="text")
        chunks_before = eng.stats["prefill_chunks"]
        slot = eng.restore(snap, eager=eager)
        if eager:
            assert not eng.is_prefilling(slot)
        else:
            assert eng.is_prefilling(slot)
            while eng.prefill_pending():
                eng.prefill_step()
                eng.step()
        assert eng.stats["prefill_chunks"] > chunks_before
        assert self._drain_all(eng, [slot])[0] == ref

    def test_partial_burst_error_carries_admitted_slots(self):
        """A burst larger than capacity raises, but the error hands back the
        slots that WERE admitted so the caller can drain/free them."""
        eng = ServingEngine(get_config("tiny"), max_slots=2, max_len=256,
                            rng_seed=0)
        prompts = [np.arange(1, 20), np.arange(1, 30), np.arange(1, 40)]
        with pytest.raises(RuntimeError, match="no free decode slot") as ei:
            eng.add_sequences([dict(prompt=p, max_new=4) for p in prompts])
        live = ei.value.admitted_slots
        assert len(live) == 2
        outs = self._drain_all(eng, live)
        assert all(len(o) == 4 for o in outs)
        assert eng.free_slot_count() == 2      # fully recovered


class TestPrefixCache:
    """Pool-wide prompt prefix caching: restore-then-extend instead of
    re-prefill, bit-exact with the cache on and off."""

    def _mk(self, cache, params=None, pc=None):
        from repro.serving import PrefixCache
        if cache and pc is None:
            pc = PrefixCache()
        return ServingEngine(get_config("tiny"), max_slots=4, max_len=256,
                             rng_seed=0, params=params, prefix_cache=pc)

    def test_exact_hit_skips_prefill(self):
        eng = self._mk(cache=True)
        prompt = np.arange(1, 33)
        first = _drain(eng, eng.add_sequence(prompt, max_new=6))
        assert eng.stats["prefills"] == 1
        second = _drain(eng, eng.add_sequence(prompt, max_new=6))
        assert eng.stats["prefills"] == 1          # prefill skipped entirely
        assert eng.stats["prefix_hits"] == 1
        assert first == second                     # and tokens identical

    def test_multi_turn_extend_bit_exact(self):
        """A grown conversation (prev prompt + prev generation + new turn)
        must decode-extend from the cached prefix and emit exactly the tokens
        the cache-off engine produces."""
        ref = self._mk(cache=False)
        eng = self._mk(cache=True, params=ref.params)

        def conversation(e):
            prompt = list(range(1, 33))
            outs = []
            for turn in range(3):
                slot = e.add_sequence(np.asarray(prompt, np.int32), max_new=6)
                while not e.is_done(slot):
                    e.step()
                g = e.result(slot)
                e.harvest_prefix(slot)
                e.free(slot)
                outs.append(list(g))
                prompt = prompt + g + [40 + turn, 50 + turn]  # new user turn
            return outs

        assert conversation(ref) == conversation(eng)
        assert ref.stats["prefills"] == 3
        assert eng.stats["prefills"] == 1          # turns 2,3 extended
        assert eng.stats["prefix_hits"] == 2
        assert eng.stats["prefix_saved_tokens"] > 0

    def test_lru_budget_eviction(self):
        from repro.serving import PrefixCache
        from repro.serving.engine import ContextSnapshot

        def snap(tokens, nbytes):
            s = ContextSnapshot(kind="prefix",
                                prompt=np.asarray(tokens, np.int32),
                                generated=[], seq_len=len(tokens),
                                state=[np.zeros(nbytes, np.uint8)])
            return s

        pc = PrefixCache(budget_bytes=4096, max_entries=8, min_tokens=4)
        assert pc.insert(snap(range(8), 1500))
        assert pc.insert(snap(range(100, 108), 1500))
        assert pc.insert(snap(range(200, 208), 1500))   # evicts the oldest
        assert pc.stats["evictions"] >= 1
        assert pc.lookup(list(range(8)) + [9]) is None  # evicted
        assert pc.lookup(list(range(200, 208)) + [9]) is not None
        assert not pc.insert(snap(range(300, 303), 64))  # below min_tokens

    def test_longest_prefix_wins(self):
        from repro.serving import PrefixCache
        from repro.serving.engine import ContextSnapshot
        pc = PrefixCache(min_tokens=2)
        base = list(range(10, 30))
        for n in (4, 8, 16):
            pc.insert(ContextSnapshot(kind="prefix",
                                      prompt=np.asarray(base[:n], np.int32),
                                      generated=[], seq_len=n, state=[]))
        hit = pc.lookup(np.asarray(base, np.int32))
        assert hit is not None and hit.seq_len == 16

    def test_suffix_extension_on_chunk_boundary(self):
        """Grown conversations whose suffix lands EXACTLY on a prefill chunk
        size (32) must extend bit-exactly -- the off-by-one hotspot of the
        chunk bucket picker."""
        ref = self._mk(cache=False)
        eng = self._mk(cache=True, params=ref.params)

        def conversation(e):
            prompt = list(range(1, 33))          # 32 tokens cached
            outs = []
            for turn in range(3):
                slot = e.add_sequence(np.asarray(prompt, np.int32), max_new=8)
                while not e.is_done(slot):
                    e.step()
                g = e.result(slot)
                e.harvest_prefix(slot)
                e.free(slot)
                outs.append(list(g))
                # longest cached prefix is the harvested prompt+generation,
                # so the next suffix = the 32 new-turn tokens: exactly one
                # full 32-token chunk
                prompt = prompt + g + [100 + turn + i for i in range(32)]
            return outs

        assert conversation(ref) == conversation(eng)
        assert eng.stats["prefix_hits"] == 2
        assert eng.stats["prefix_extend_tokens"] == 64   # 2 turns x 32
        assert eng.stats["prefills"] == 1

    def test_eviction_mid_extension_under_tight_budget(self):
        """A tight byte budget can evict the very entry a sequence is
        extending from (the completion re-insert of the grown prefix pushes
        it out). The in-flight extension holds its own reference, so tokens
        must stay exact and the engine must not crash."""
        from repro.serving import PrefixCache
        ref = self._mk(cache=False)
        probe = self._mk(cache=True, params=ref.params)
        # measure one entry's size, then budget for ~1.5 entries
        slot = probe.add_sequence(np.arange(1, 33), max_new=4)
        while not probe.is_done(slot):
            probe.step()
        probe.free(slot)
        entry_bytes = probe.prefix_cache.used_bytes
        pc = PrefixCache(budget_bytes=int(entry_bytes * 1.5), max_entries=8)
        eng = ServingEngine(get_config("tiny"), max_slots=4, max_len=256,
                            rng_seed=0, params=ref.params, prefix_cache=pc)

        def conversation(e):
            prompt = list(range(1, 33))
            outs = []
            for turn in range(3):
                slot = e.add_sequence(np.asarray(prompt, np.int32), max_new=6)
                while not e.is_done(slot):
                    e.step()
                g = e.result(slot)
                e.harvest_prefix(slot)
                e.free(slot)
                outs.append(list(g))
                prompt = prompt + g + [60 + turn, 70 + turn]
            return outs

        assert conversation(ref) == conversation(eng)
        assert pc.stats["evictions"] >= 1            # budget forced churn
        assert eng.stats["prefix_hits"] >= 1         # reuse still happened

    def test_prefix_hit_after_cross_core_migration(self):
        """Pool scenario: a sequence prefills on core A, is preempted and
        migrated to core B (snapshot/restore), finishes and is harvested
        there -- the next grown resubmission on core A must hit the SHARED
        prefix cache and stay bit-exact."""
        from repro.serving import PrefixCache
        pc = PrefixCache()
        ref = self._mk(cache=False)                       # oracle, no cache
        core_a = self._mk(cache=True, params=ref.params, pc=pc)
        core_b = self._mk(cache=True, params=ref.params, pc=pc)

        def finish(e, slot):
            while not e.is_done(slot):
                e.step()
            g = e.result(slot)
            e.harvest_prefix(slot)
            e.free(slot)
            return g

        prompt = np.arange(1, 41)
        g_ref = finish(ref, ref.add_sequence(prompt, max_new=8))
        grown_ref = list(prompt) + g_ref + [90, 91]
        g2_ref = finish(ref, ref.add_sequence(np.asarray(grown_ref, np.int32),
                                              max_new=8))

        slot = core_a.add_sequence(prompt, max_new=8)
        for _ in range(3):
            core_a.step()
        snap = core_a.snapshot(slot)                      # preempt on A ...
        slot = core_b.restore(snap)                       # ... migrate to B
        g = finish(core_b, slot)
        assert g == g_ref
        grown = list(prompt) + g + [90, 91]
        prefills_before = core_a.stats["prefills"]
        g2 = finish(core_a, core_a.add_sequence(np.asarray(grown, np.int32),
                                                max_new=8))
        assert g2 == g2_ref
        assert core_a.stats["prefills"] == prefills_before   # extended, not re-prefilled
        assert core_a.stats["prefix_hits"] >= 1
        assert pc.stats["hits"] >= 1

    def test_pool_shares_prefix_across_cores(self):
        """A prefix prefilled on one core must be a hit on any core: the
        kernel gives every core the same PrefixCache instance."""
        from repro.core import AIOSKernel
        from repro.sdk.query import LLMQuery
        k = AIOSKernel(arch="tiny", scheduler="batched", num_cores=2,
                       engine_kw={"max_slots": 2, "max_len": 256})
        assert (k.pool.cores[0].engine.prefix_cache
                is k.pool.cores[1].engine.prefix_cache)
        with k:
            prompt = list(range(1, 33))
            outs = []
            for i in range(3):                      # sequential resubmissions
                sc = LLMQuery(prompt=prompt,
                              max_new_tokens=6).to_syscall(f"share{i}")
                k.submit(sc)
                outs.append(sc.join(timeout=300)["tokens"])
            m = k.metrics()
        assert outs[0] == outs[1] == outs[2]
        assert m["prefix_cache"]["hits"] >= 2
        total_prefills = sum(e["prefills"] for e in m["engine"])
        assert total_prefills <= 1                  # only the first admission


class TestWarmup:
    def test_warmup_compiles_without_changing_tokens(self):
        """warmup() pre-compiles the serving program grid; generation after a
        warm pass is bit-identical to a cold engine with the same seed."""
        cfg = get_config("tiny")
        prompts = [np.arange(1, 9), np.arange(3, 40, 2)]
        cold = ServingEngine(cfg, max_slots=4, max_len=128, rng_seed=0)
        expect = [_drain(cold, cold.add_sequence(p, max_new=8))
                  for p in prompts]

        warm = ServingEngine(cfg, max_slots=4, max_len=128, rng_seed=0,
                             params=cold.params)
        ran = warm.warmup(buckets=(32, 64))
        assert ran > 0
        assert warm.free_slot_count() == warm.max_slots   # all drained
        assert warm.pager.used_pages == 0
        out = [_drain(warm, warm.add_sequence(p, max_new=8))
               for p in prompts]
        assert out == expect

    def test_warmup_leaves_prefix_cache_empty(self):
        from repro.serving import PrefixCache
        pc = PrefixCache()
        eng = ServingEngine(get_config("tiny"), max_slots=2, max_len=128,
                            rng_seed=0, prefix_cache=pc)
        eng.warmup(buckets=(32,))
        assert len(pc) == 0                 # warm prompts never cached
        assert eng.prefix_cache is pc       # reattached after warming
