"""Serving engine: admission control (no trial-and-error), page accounting,
context-switch exactness (paper Table 7), batch-composition independence."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import PageAllocator, ServingEngine


@pytest.fixture(scope="module")
def engine():
    return ServingEngine(get_config("tiny"), max_slots=4, max_len=128,
                         rng_seed=0)


def _drain(eng, slot):
    while not eng.is_done(slot):
        eng.step()
    out = eng.result(slot)
    eng.free(slot)
    return out


class TestPaging:
    def test_reserve_grow_release(self):
        pa = PageAllocator(num_pages=10, page_size=16)
        assert pa.reserve("s0", 40)          # 3 pages
        assert pa.used_pages == 3
        assert pa.grow("s0", 70)             # -> 5 pages
        assert pa.held("s0") == 5
        assert not pa.reserve("s1", 100)     # 7 > 5 free
        assert pa.failed_reservations == 1
        assert pa.release("s0") == 5
        assert pa.free_pages == 10

    def test_admission_never_overcommits(self):
        pa = PageAllocator(num_pages=4, page_size=16)
        assert pa.can_admit(64)
        assert not pa.can_admit(65)


class TestEngine:
    def test_generate_and_free(self, engine):
        slot = engine.add_sequence(np.arange(1, 9), max_new=8)
        out = _drain(engine, slot)
        assert len(out) == 8
        assert engine.free_slot_count() == engine.max_slots

    def test_admission_rejects_when_full(self, engine):
        slots = [engine.add_sequence(np.arange(1, 5), max_new=4)
                 for _ in range(engine.max_slots)]
        with pytest.raises(RuntimeError):
            engine.add_sequence(np.arange(1, 5), max_new=4)
        for s in slots:
            _drain(engine, s)

    def test_context_too_long_rejected(self, engine):
        with pytest.raises(RuntimeError):
            engine.add_sequence(np.arange(1, 100), max_new=100)

    def test_batch_composition_independence(self):
        """A sequence's output must not depend on what else is in the batch."""
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=4, max_len=128, rng_seed=0)
        prompt = np.arange(1, 9)
        alone = _drain(eng, eng.add_sequence(prompt, max_new=10))
        # same prompt co-batched with others
        others = [eng.add_sequence(np.arange(2, 20, 2), max_new=10),
                  eng.add_sequence(np.array([9, 8, 7]), max_new=10)]
        mine = eng.add_sequence(prompt, max_new=10)
        while not eng.is_done(mine):
            eng.step()
        together = eng.result(mine)
        assert alone == together

    @pytest.mark.parametrize("kind", ["logits", "text"])
    @pytest.mark.parametrize("temperature", [0.0, 0.7])
    def test_context_switch_exact(self, kind, temperature):
        """Paper Table 7: outputs with and without a mid-generation context
        switch must match exactly (BLEU/BERTScore 1.0 <=> identical ids)."""
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=4, max_len=128,
                            temperature=temperature, rng_seed=1)
        prompt = np.arange(1, 9)
        ref = _drain(eng, eng.add_sequence(prompt, max_new=12))

        slot = eng.add_sequence(prompt, max_new=12)
        for _ in range(5):
            eng.step()
        snap = eng.snapshot(slot, kind=kind)
        # interleave unrelated work
        other = eng.add_sequence(np.arange(5, 50, 5), max_new=6)
        _drain(eng, other)
        slot = eng.restore(snap)
        out = _drain(eng, slot)
        assert out == ref, (kind, temperature)

    def test_snapshot_accounting(self):
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=2, max_len=128, rng_seed=2)
        slot = eng.add_sequence(np.arange(1, 9), max_new=8)
        used_before = eng.pager.used_pages
        assert used_before > 0
        eng.step()
        snap = eng.snapshot(slot)
        assert eng.pager.used_pages == 0          # pages released on preempt
        assert snap.nbytes() > 0                  # host pool now holds state
        slot = eng.restore(snap)
        assert eng.pager.used_pages > 0
        _drain(eng, slot)

    def test_failed_load_probe_counts(self):
        cfg = get_config("tiny")
        eng = ServingEngine(cfg, max_slots=1, max_len=64, rng_seed=3)
        s = eng.add_sequence(np.arange(1, 5), max_new=4)
        eng.probe_failed_load(np.arange(1, 9))
        assert eng.stats["failed_loads"] == 1
        _drain(eng, s)
