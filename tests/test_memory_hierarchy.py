"""Unified paged KV memory hierarchy: copy-on-write page sharing, tier
demotion (device -> host -> disk), cross-process prefix re-hydration,
bit-exactness of the page-store path vs the legacy blob path, and the
control-plane features built on page identity (fractional affinity, the
migration victim cost model, the SLO admission controller, p90 planning)."""
import os
import tempfile
from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs import get_config
from repro.control.plane import ControlPlane
from repro.control.rebalancer import (Rebalancer, migration_cost,
                                      pick_migration_victim)
from repro.control.telemetry import TelemetryBus
from repro.core import AIOSKernel
from repro.core.context import ContextManager
from repro.core.storage import StorageManager
from repro.core.syscall import LLMSyscall
from repro.memory import KVPageStore
from repro.sdk.query import LLMQuery
from repro.serving import PrefixCache, ServingEngine

TINY = get_config("tiny")


def _drain(eng, slot):
    while not eng.is_done(slot):
        eng.step()
    out = eng.result(slot)
    eng.free(slot)
    return out


def _store(storage=None, **kw):
    kw.setdefault("page_size", 16)
    return KVPageStore(storage=storage, **kw)


# ---------------------------------------------------------------------------
# page store unit level (synthetic layout, no model)
# ---------------------------------------------------------------------------
class TestPageStore:
    LAYOUT = "unit|len64"

    def _mk(self, **kw):
        st = _store(**kw)
        st.register_layout(self.LAYOUT, [1, None], [(1, 64, 2), (1,)],
                           [np.float32, np.int32])
        return st

    def test_roundtrip_and_cow_refcounts(self):
        st = self._mk()
        rng = np.random.default_rng(0)
        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :48] = rng.normal(size=(48, 2))
        h1 = st.put(self.LAYOUT, [kv, np.array([48], np.int32)], seq_len=48,
                    origin=0)
        assert len(h1.page_ids) == 3
        # extension: same first 48 positions, 16 more tokens -> the full
        # pages dedupe (copy-on-write), only the new boundary page is fresh
        kv2 = kv.copy()
        kv2[0, 48:64] = rng.normal(size=(16, 2))
        h2 = st.put(self.LAYOUT, [kv2, np.array([64], np.int32)], seq_len=64,
                    origin=1)
        assert st.stats["dedup_hits"] == 3
        assert st.stats["dedup_saved_bytes"] > 0
        shared = [st.table.get(p) for p in h1.page_ids]
        assert all(p.refs == 2 for p in shared)
        assert st.page_origins(h2) == [0, 0, 0, 1]   # boundary page only
        # bit-exact reassembly (zeros beyond seq_len by construction here)
        l1 = st.leaves(h1)
        np.testing.assert_array_equal(l1[0], kv)
        np.testing.assert_array_equal(st.leaves(h2)[0], kv2)
        # release drops refcounts; refcount-0 unpersisted pages are freed
        h1.release()
        assert all(p.refs == 1 for p in shared)
        h1.release()                                  # idempotent
        assert all(p.refs == 1 for p in shared)
        h2.release()
        assert len(st.table) == 0

    def test_device_budget_pressure_demotes(self):
        st = self._mk(device_pages=2)
        rng = np.random.default_rng(1)
        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :64] = rng.normal(size=(64, 2))
        h = st.put(self.LAYOUT, [kv, np.array([64], np.int32)], seq_len=64,
                   origin=0, device=True)
        # 4 pages into a 2-page device budget: LRU pages demoted to host
        assert st.device_pager.used_pages <= 2
        assert st.stats["demotions_host"] >= 2
        m = st.metrics()
        assert m["device_pages"] <= 2 and m["host_pages"] >= 2
        np.testing.assert_array_equal(st.leaves(h)[0], kv)   # still exact

    def test_host_watermark_demotes_to_disk_and_promotes(self):
        storage = StorageManager(tempfile.mkdtemp(prefix="kvst-"))
        st = self._mk(storage=storage, host_budget_bytes=1)
        rng = np.random.default_rng(2)
        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :32] = rng.normal(size=(32, 2))
        h = st.put(self.LAYOUT, [kv, np.array([32], np.int32)], seq_len=32)
        assert st.stats["demotions_disk"] >= 2        # over the 1-byte budget
        assert st.host_used() <= 1
        np.testing.assert_array_equal(st.leaves(h)[0], kv)   # disk promote
        assert st.stats["promotions"] >= 1


# ---------------------------------------------------------------------------
# quantized page tiers: int8 off-device precision as a tier property
# ---------------------------------------------------------------------------
class TestQuantizedTiers:
    LAYOUT = "q|len64"

    def _mk(self, **kw):
        kw.setdefault("kv_quant", "int8")
        st = _store(**kw)
        st.register_layout(self.LAYOUT, [1, None], [(1, 64, 2), (1,)],
                           [np.float32, np.int32])
        return st

    def _kv(self, seed, n=48):
        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :n] = np.random.default_rng(seed).normal(size=(n, 2))
        return kv

    def test_host_landing_quantizes_and_dequantizes_on_read(self):
        st = self._mk()
        kv = self._kv(0)
        h = st.put(self.LAYOUT, [kv, np.array([48], np.int32)], seq_len=48)
        assert st.stats["quantized_pages"] == 3
        assert st.stats["quant_saved_bytes"] > 0
        # int8 host residency: actual bytes well under the attributed fp
        # size the handle accounts with (scales ride along)
        assert st.host_used() < sum(p.nbytes for p in st.table.pages())
        got = st.leaves(h)[0]
        # per-channel symmetric int8: bounded error, not bit-equality
        err = np.abs(got - kv).max()
        assert 0 < err < 0.05
        # the unpaged leaf (no time axis -> never quantized) stays exact
        np.testing.assert_array_equal(
            st.leaves(h)[1], np.array([48], np.int32))
        h.release()
        assert len(st.table) == 0

    def test_dedup_and_refcounts_hold_across_quantized_pages(self):
        """CoW identity is keyed on the ORIGINAL fp bytes: a second put of
        the same content dedupes onto the already-quantized host pages, and
        release/refcount semantics are unchanged by the tier precision."""
        st = self._mk()
        kv = self._kv(1)
        h1 = st.put(self.LAYOUT, [kv, np.array([48], np.int32)], seq_len=48)
        quantized_once = st.stats["quantized_pages"]
        kv2 = kv.copy()
        kv2[0, 48:64] = np.random.default_rng(2).normal(size=(16, 2))
        h2 = st.put(self.LAYOUT, [kv2, np.array([64], np.int32)], seq_len=64)
        assert st.stats["dedup_hits"] == 3
        shared = [st.table.get(p) for p in h1.page_ids]
        assert all(p.refs == 2 for p in shared)
        assert all(p.scales is not None for p in shared)
        # dedup re-referenced the existing int8 pages: no re-quantization
        assert st.stats["quantized_pages"] == quantized_once + 1
        # both handles read through the same quantized pages consistently
        np.testing.assert_array_equal(st.leaves(h1)[0][0, :48],
                                      st.leaves(h2)[0][0, :48])
        h1.release()
        assert all(p.refs == 1 for p in shared)
        h2.release()
        assert len(st.table) == 0

    def test_demote_promote_roundtrip_through_disk(self):
        """int8 pages flushed to the v2 disk blob and promoted back read
        identically to their pre-demotion host form (quantize once: the
        disk round trip adds NO further error)."""
        storage = StorageManager(tempfile.mkdtemp(prefix="kvq-"))
        st = self._mk(storage=storage)
        kv = self._kv(3)
        h = st.put(self.LAYOUT, [kv, np.array([48], np.int32)], seq_len=48)
        before = st.leaves(h)[0]
        assert st.demote_handle(h)
        assert st.metrics()["disk_pages"] >= 3
        after = st.leaves(h)[0]            # promote from the v2 blob
        assert st.stats["promotions"] >= 3
        np.testing.assert_array_equal(before, after)
        assert np.abs(after - kv).max() < 0.05
        h.release()

    def test_device_tier_stays_full_precision(self):
        """Device-resident pages are never quantized -- precision is a
        property of the tier, and demotion under budget pressure is the
        quantization point."""
        st = self._mk(device_pages=2)
        kv = self._kv(4, n=64)
        h = st.put(self.LAYOUT, [kv, np.array([64], np.int32)], seq_len=64,
                   device=True)
        # 4 pages into a 2-page device budget: LRU pages demoted+quantized,
        # the survivors still fp on device
        assert st.stats["demotions_host"] >= 2
        assert st.stats["quantized_pages"] >= 2
        on_dev = [p for p in st.table.pages() if p.tier == "device"]
        assert on_dev and all(p.scales is None for p in on_dev)
        got = st.leaves(h)[0]
        assert np.abs(got - kv).max() < 0.05
        h.release()

    def test_kv_quant_off_is_bit_exact(self):
        storage = StorageManager(tempfile.mkdtemp(prefix="kvoff-"))
        st = self._mk(storage=storage, kv_quant="off")
        kv = self._kv(5)
        h = st.put(self.LAYOUT, [kv, np.array([48], np.int32)], seq_len=48)
        assert st.demote_handle(h)
        np.testing.assert_array_equal(st.leaves(h)[0], kv)
        assert st.stats["quantized_pages"] == 0
        assert st.metrics()["kv_quant"] == "off"

    WIDE = "qp|64x128"

    def _mk_wide(self, root, kv_quant):
        st = _store(storage=StorageManager(root), kv_quant=kv_quant)
        st.register_layout(self.WIDE, [1], [(1, 64, 128)], [np.float32])
        return st

    def _persist_bytes(self, root, kv_quant):
        """Persist one 48-token prefix under ``kv_quant`` and return
        (fresh-store-on-same-root, kv, page-blob bytes on disk)."""
        st = self._mk_wide(root, kv_quant)
        kv = np.zeros((1, 64, 128), np.float32)
        kv[0, :48] = np.random.default_rng(6).normal(size=(48, 128))
        snap = SimpleNamespace(
            pages=st.put(self.WIDE, [kv], seq_len=48, device=True),
            prompt=np.arange(200, 248, dtype=np.int32), seq_len=48,
            logits=np.zeros(8, np.float32), origin=0)
        assert st.persist_prefix(snap)
        pages_dir = os.path.join(root, ".blobs", "kvpages")
        nbytes = sum(os.path.getsize(os.path.join(pages_dir, f))
                     for f in os.listdir(pages_dir))
        fresh = self._mk_wide(root, kv_quant)
        return fresh, kv, nbytes

    def test_quantize_on_persist_rehydrates_int8_blobs(self):
        """Quantize-on-persist round trip across 'processes': the disk
        blobs a device-tier persist writes are int8 (re-hydration I/O sees
        the byte savings, not just demotion), and a fresh store on the same
        root reads them back within the one-step quantization tolerance."""
        fresh, kv, int8_bytes = self._persist_bytes(
            tempfile.mkdtemp(prefix="kvqp-"), "int8")
        _, _, fp_bytes = self._persist_bytes(
            tempfile.mkdtemp(prefix="kvfp-"), "off")
        assert int8_bytes < 0.7 * fp_bytes   # ~1.84x smaller paged leaf
        entry = fresh.rehydrate_prefix(
            np.arange(200, 250, dtype=np.int32))
        assert entry is not None and entry.seq_len == 48
        got = entry.pages.leaves()[0]
        err = np.abs(got - kv).max()
        assert 0 < err < 0.05                # int8 came off disk, not fp
        assert fresh.stats["rehydrated_entries"] == 1
        loaded = [fresh.table.get(p) for p in entry.pages.page_ids]
        assert all(p.scales is not None for p in loaded)


# ---------------------------------------------------------------------------
# prefix-probe gate: O(1) reject before the manifest scan
# ---------------------------------------------------------------------------
class TestPrefixProbeGate:
    LAY = "gate|64"

    def _mk(self, root, **kw):
        st = KVPageStore(page_size=16, storage=StorageManager(root), **kw)
        st.register_layout(self.LAY, [1], [(1, 64, 2)], [np.float32])
        return st

    def test_nonmatching_probe_is_gated_matching_rehydrates(self):
        root = tempfile.mkdtemp(prefix="kvgate-")
        st = self._mk(root)
        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :32] = np.random.default_rng(6).normal(size=(32, 2))
        prompt = np.arange(100, 132, dtype=np.int32)
        snap = SimpleNamespace(pages=st.put(self.LAY, [kv], seq_len=32),
                               prompt=prompt, seq_len=32,
                               logits=np.zeros(8, np.float32), origin=0)
        assert st.persist_prefix(snap)
        # fresh store, same root ("another process"): first probe builds
        # the gate from the manifest index
        fresh = self._mk(root)
        miss = np.arange(500, 532, dtype=np.int32)   # shares no lead tokens
        assert fresh.rehydrate_prefix(miss) is None
        assert fresh.stats["gated_probes"] == 1
        assert fresh.metrics()["gated_probes"] == 1
        # the gate is exact -- no false negatives: the real prefix (plus a
        # divergent tail) still rehydrates, without a gated count
        hit = np.concatenate([prompt, np.array([7, 9], np.int32)])
        entry = fresh.rehydrate_prefix(hit)
        assert entry is not None
        np.testing.assert_array_equal(entry.pages.leaves()[0], kv)
        assert fresh.stats["gated_probes"] == 1
        # a probe matching only the first gate_tokens lead tokens passes
        # the gate (not counted) but misses in the full scan
        near = np.concatenate([prompt[:st.gate_tokens],
                               np.arange(900, 910, dtype=np.int32)])
        assert fresh.rehydrate_prefix(near) is None
        assert fresh.stats["gated_probes"] == 1

    def test_short_probe_never_false_negative(self):
        """A probe shorter than gate_tokens must still match manifests via
        their clipped keys (clip lengths adapt per entry)."""
        root = tempfile.mkdtemp(prefix="kvgate2-")
        st = self._mk(root, gate_tokens=16)
        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :16] = np.random.default_rng(7).normal(size=(16, 2))
        prompt = np.arange(40, 56, dtype=np.int32)
        snap = SimpleNamespace(pages=st.put(self.LAY, [kv], seq_len=16),
                               prompt=prompt, seq_len=16,
                               logits=np.zeros(8, np.float32), origin=0)
        assert st.persist_prefix(snap)
        fresh = self._mk(root, gate_tokens=16)
        entry = fresh.rehydrate_prefix(
            np.concatenate([prompt, np.array([3], np.int32)]))
        assert entry is not None
        assert fresh.stats["gated_probes"] == 0


# ---------------------------------------------------------------------------
# sub-prefix re-hydration: page-boundary truncation of longer donors
# ---------------------------------------------------------------------------
class TestTruncatedRehydrate:
    LAY = "trunc|64"

    def _mk(self, root, truncatable=True):
        st = KVPageStore(page_size=16, storage=StorageManager(root))
        st.register_layout(self.LAY, [1], [(1, 64, 2)], [np.float32],
                           truncatable=truncatable)
        return st

    def _persist(self, st, n=48):
        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :n] = np.random.default_rng(9).normal(size=(n, 2))
        prompt = np.arange(300, 300 + n, dtype=np.int32)
        snap = SimpleNamespace(pages=st.put(self.LAY, [kv], seq_len=n),
                               prompt=prompt, seq_len=n,
                               logits=np.zeros(8, np.float32), origin=0)
        assert st.persist_prefix(snap)
        return kv, prompt

    def test_shorter_probe_truncates_at_page_boundary(self):
        """A persisted 48-token prefix serves a probe that diverges at
        token 40: the donor's first 2 pages (32 tokens -- the largest page
        boundary inside the shared region) come back as a truncated entry
        with no logits (they followed the longer context)."""
        root = tempfile.mkdtemp(prefix="kvtr-")
        kv, prompt = self._persist(self._mk(root))
        fresh = self._mk(root)
        probe = np.concatenate([prompt[:40],
                                np.arange(700, 708, dtype=np.int32)])
        entry = fresh.rehydrate_prefix(probe)
        assert entry is not None
        assert entry.seq_len == 32 and len(entry.prompt) == 32
        assert entry.logits is None
        np.testing.assert_array_equal(entry.pages.leaves()[0][0, :32],
                                      kv[0, :32])
        assert fresh.stats["truncated_rehydrates"] == 1
        # a whole-manifest prefix match still beats truncation
        exact = np.concatenate([prompt, np.array([5], np.int32)])
        e2 = fresh.rehydrate_prefix(exact)
        assert e2 is not None and e2.seq_len == 48
        assert e2.logits is not None
        assert fresh.stats["truncated_rehydrates"] == 1

    def test_stateful_layout_never_truncates(self):
        """Layouts whose residual state can't rewind to a page boundary
        (registered truncatable=False -- the same contract that gates
        speculative rollback) must miss rather than serve a cut donor."""
        root = tempfile.mkdtemp(prefix="kvtr2-")
        _, prompt = self._persist(self._mk(root, truncatable=False))
        fresh = self._mk(root, truncatable=False)
        probe = np.concatenate([prompt[:40],
                                np.arange(700, 708, dtype=np.int32)])
        assert fresh.rehydrate_prefix(probe) is None
        assert fresh.stats["truncated_rehydrates"] == 0
        # exact whole-prefix re-hydration is unaffected by the gate
        assert fresh.rehydrate_prefix(
            np.concatenate([prompt, [5]]).astype(np.int32)) is not None

    def test_engine_end_to_end_matches_cold_prefill(self):
        """Cross-process flow: engine A persists a 48-token prompt; engine
        B (fresh store, same root) submits a probe sharing 40 lead tokens.
        B re-prefills only from the 32-token cut and its tokens equal a
        cold engine's."""
        root = tempfile.mkdtemp(prefix="kvtr3-")

        def mk_eng():
            # same rng_seed everywhere: it seeds the model params, and the
            # donor's pages are only valid under the donor's weights
            st = _store(storage=StorageManager(root))
            return st, ServingEngine(TINY, max_slots=2, max_len=128,
                                     rng_seed=3,
                                     prefix_cache=PrefixCache(page_store=st),
                                     page_store=st)
        rng = np.random.default_rng(10)
        prompt = rng.integers(1, TINY.vocab - 1, 48).astype(np.int32)
        st1, eng1 = mk_eng()
        _drain(eng1, eng1.add_sequence(prompt, max_new=4))
        assert st1.stats["persisted_entries"] >= 1
        probe = np.concatenate(
            [prompt[:40], rng.integers(1, TINY.vocab - 1, 8)]).astype(np.int32)
        st2, eng2 = mk_eng()
        got = _drain(eng2, eng2.add_sequence(probe, max_new=8))
        assert st2.stats["truncated_rehydrates"] == 1
        cold = ServingEngine(TINY, max_slots=2, max_len=128, rng_seed=3)
        assert got == _drain(cold, cold.add_sequence(probe, max_new=8))


# ---------------------------------------------------------------------------
# engine level: paged snapshots, prefix CoW, bit-exactness vs legacy
# ---------------------------------------------------------------------------
class TestEnginePaged:
    @pytest.mark.parametrize("temperature", [0.0, 0.7])
    def test_snapshot_restore_bitexact_vs_legacy(self, temperature):
        prompt = np.arange(1, 9)
        ref_eng = ServingEngine(TINY, max_slots=4, max_len=128,
                                temperature=temperature, rng_seed=1)
        ref = _drain(ref_eng, ref_eng.add_sequence(prompt, max_new=12))
        eng = ServingEngine(TINY, max_slots=4, max_len=128,
                            temperature=temperature, rng_seed=1,
                            page_store=_store())
        slot = eng.add_sequence(prompt, max_new=12)
        for _ in range(5):
            eng.step()
        snap = eng.snapshot(slot)
        assert snap.state is None and snap.pages is not None
        other = eng.add_sequence(np.arange(5, 50, 5), max_new=6)
        _drain(eng, other)
        slot = eng.restore(snap)
        out = _drain(eng, slot)
        snap.release()
        assert out == ref, temperature

    def test_prefix_cow_sharing_and_release(self):
        st = _store()
        pc = PrefixCache(page_store=st)
        eng = ServingEngine(TINY, max_slots=4, max_len=128, rng_seed=0,
                            prefix_cache=pc, page_store=st)
        prompt = np.arange(1, 33)            # 32 tokens = 2 full pages
        slot = eng.add_sequence(prompt, max_new=6)
        while not eng.is_done(slot):
            eng.step()
        eng.harvest_prefix(slot)             # entry for prompt + generation
        out = eng.result(slot)
        eng.free(slot)
        # the harvest's pages over [0, 32) dedupe against the post-prefill
        # entry's pages: copy-on-write sharing, refcount 2
        assert eng.stats["prefix_hits"] == 0
        assert st.stats["dedup_hits"] >= 2
        assert sum(1 for p in st.table.pages() if p.refs == 2) >= 2
        # the grown resubmission is an exact hit on the harvested entry
        grown = np.concatenate([prompt, np.asarray(out, np.int32)])
        slot = eng.add_sequence(grown, max_new=4)
        assert eng.stats["prefix_hits"] == 1
        _drain(eng, slot)
        # eviction releases pages; with no disk tier they are freed outright
        pc.clear()
        assert len(st.table) == 0
        assert st.device_pager.used_pages == 0

    def test_restore_then_extend_bitexact(self):
        """Prefix-cache suffix extension through the page store matches the
        uncached engine token-for-token."""
        ref_eng = ServingEngine(TINY, max_slots=4, max_len=128, rng_seed=0)
        st = _store()
        eng = ServingEngine(TINY, max_slots=4, max_len=128, rng_seed=0,
                            prefix_cache=PrefixCache(page_store=st),
                            page_store=st)
        p1 = np.arange(1, 25)
        out1 = _drain(eng, eng.add_sequence(p1, max_new=6))
        assert out1 == _drain(ref_eng, ref_eng.add_sequence(p1, max_new=6))
        grown = np.concatenate([p1, np.asarray(out1, np.int32),
                                np.array([7, 9, 11], np.int32)])
        slot = eng.add_sequence(grown, max_new=6, eager=False)
        while eng.prefill_pending():
            eng.prefill_step()
        out2 = _drain(eng, slot)
        assert eng.stats["prefix_hits"] >= 1
        assert out2 == _drain(ref_eng, ref_eng.add_sequence(grown, max_new=6))


# ---------------------------------------------------------------------------
# kernel level: pool bit-exactness, spill tier, cross-process re-hydration
# ---------------------------------------------------------------------------
def _run_kernel(prompts, *, paged, root_dir=None, max_new=8, **kkw):
    k = AIOSKernel(arch="tiny", scheduler="batched", num_cores=2, quantum=4,
                   paged_kv=paged, root_dir=root_dir,
                   engine_kw={"max_slots": 4, "max_len": 128}, **kkw)
    k.start()
    outs = [k.send_request("t", LLMQuery(prompt=p, max_new_tokens=max_new))
            ["tokens"] for p in prompts]
    m = k.metrics()
    k.stop()
    return outs, m


class TestKernelPaged:
    PROMPTS = [list(range(5, 45)), list(range(5, 45)) + [7, 9, 2],
               [3, 1, 4, 1, 5, 9, 2, 6] * 4, list(range(2, 30, 3))]

    def test_pool_bitexact_paged_vs_legacy(self):
        """Same tokens with the page store on vs the legacy snapshot path --
        through the batched pool with quantum suspends, prefix hits and
        restore-then-extend (sequential submission keeps it deterministic)."""
        on, m_on = _run_kernel(self.PROMPTS, paged=True)
        off, m_off = _run_kernel(self.PROMPTS, paged=False)
        assert on == off
        assert "kv_store" in m_on and "kv_store" not in m_off
        assert m_on["kv_store"]["put_handles"] > 0

    def test_fresh_kernel_rehydrates_from_storage_tier(self):
        root = tempfile.mkdtemp(prefix="kv-shared-")
        out1, m1 = _run_kernel(self.PROMPTS[:2], paged=True, root_dir=root)
        assert m1["kv_store"]["persisted_entries"] > 0
        # a process-equivalent fresh kernel on the same root: prefixes come
        # back from the disk manifests, tokens identical
        out2, m2 = _run_kernel(self.PROMPTS[:2], paged=True, root_dir=root)
        assert out2 == out1
        assert m2["prefix_cache"]["rehydrates"] >= 1
        assert m2["kv_store"]["rehydrated_entries"] >= 1
        assert m2["prefix_cache"]["hits"] >= 1

    def test_rehydrate_respects_local_budget(self):
        """An entry persisted under a bigger budget than this process runs
        with is skipped (counted as a miss), not admitted destructively."""
        storage = StorageManager(tempfile.mkdtemp(prefix="kvbud-"))
        st = _store(storage=storage)
        pc = PrefixCache(page_store=st)
        eng = ServingEngine(TINY, max_slots=2, max_len=128, rng_seed=6,
                            prefix_cache=pc, page_store=st)
        prompt = np.arange(1, 40)
        _drain(eng, eng.add_sequence(prompt, max_new=4))
        assert st.stats["persisted_entries"] >= 1
        # fresh tiny-budget cache on the same store: the persisted entry is
        # bigger than the whole budget -- lookup must miss, not crash
        small = PrefixCache(budget_bytes=16, page_store=st)
        assert small.lookup(np.concatenate([prompt, [7]])) is None
        assert small.stats["misses"] == 1

    def test_free_never_deletes_blobs_shared_with_manifests(self):
        """Content-addressed blobs are shared by identity: process B
        freeing its non-durable copy of pages that process A's persisted
        manifest lists must not delete A's blobs (pre-fix this poisoned
        every later rehydrate with KeyError)."""
        root = tempfile.mkdtemp(prefix="kv-poison-")
        lay = "t|64"

        def mk():
            st = KVPageStore(page_size=16, storage=StorageManager(root))
            st.register_layout(lay, [1], [(1, 64, 2)], [np.float32])
            return st

        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :32] = np.random.default_rng(8).normal(size=(32, 2))
        a = mk()
        snap = SimpleNamespace(pages=a.put(lay, [kv], seq_len=32, origin=0),
                               prompt=np.arange(32), seq_len=32,
                               logits=np.zeros(8, np.float32), origin=0)
        assert a.persist_prefix(snap)
        b = mk()                       # "another process", same root
        hb = b.put(lay, [kv], seq_len=32)
        assert b.demote_handle(hb)     # flushes the same content pids
        hb.release()                   # refcount 0, non-durable -> freed
        c = mk()
        entry = c.rehydrate_prefix(np.arange(32))
        assert entry is not None
        np.testing.assert_array_equal(entry.pages.leaves()[0], kv)

    def test_context_spill_through_page_tier(self):
        """A paged snapshot spilled by the ContextManager demotes its pages
        to disk (no whole-blob pickle) and restores bit-exactly."""
        storage = StorageManager(tempfile.mkdtemp(prefix="kvspill-"))
        st = _store(storage=storage)
        cm = ContextManager(storage, budget_bytes=1, watermark=0.0,
                            page_store=st)
        eng = ServingEngine(TINY, max_slots=2, max_len=128, rng_seed=4,
                            page_store=st)
        prompt = np.arange(1, 20)
        ref = _drain(eng, eng.add_sequence(prompt, max_new=10))
        slot = eng.add_sequence(prompt, max_new=10)
        for _ in range(4):
            eng.step()
        cm.save("c1", eng.snapshot(slot))
        assert cm.stats["spills"] >= 1
        assert st.metrics()["disk_pages"] >= 1
        snap = cm.load("c1")
        out = _drain(eng, eng.restore(snap))
        cm.clear("c1")
        assert out == ref
        assert len(st.table) == 0      # cleared context returned its pages


# ---------------------------------------------------------------------------
# orphan page-blob GC (mark-and-sweep over surviving manifests)
# ---------------------------------------------------------------------------
class TestOrphanBlobGC:
    LAY = "gc|64"

    def _mk(self, root):
        st = KVPageStore(page_size=16, storage=StorageManager(root),
                         max_manifests=2)
        st.register_layout(self.LAY, [1], [(1, 64, 2)], [np.float32])
        return st

    def _persist(self, st, seed, n=32):
        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :n] = np.random.default_rng(seed).normal(size=(n, 2))
        prompt = np.random.default_rng(seed).integers(1, 99, n)
        snap = SimpleNamespace(pages=st.put(self.LAY, [kv], seq_len=n),
                               prompt=prompt.astype(np.int32), seq_len=n,
                               logits=np.zeros(8, np.float32), origin=0)
        assert st.persist_prefix(snap)
        return snap, kv

    @staticmethod
    def _blob_count(root):
        import os
        d = os.path.join(root, ".blobs", "kvpages")
        return len([f for f in os.listdir(d)
                    if not f.endswith(".tmp")]) if os.path.isdir(d) else 0

    def test_manifest_pruning_orphans_are_reclaimed(self):
        """max_manifests=2: persisting 4 prefixes prunes the 2 oldest
        manifests but leaves their page blobs -- the sweep reclaims exactly
        those, and the surviving prefixes still re-hydrate bit-exactly."""
        root = tempfile.mkdtemp(prefix="kvgc-")
        st = self._mk(root)
        snaps = [self._persist(st, seed) for seed in range(4)]
        for snap, _ in snaps:
            snap.pages.release()       # durable pages retire; blobs stay
        before = self._blob_count(root)
        assert before == 8             # 4 prefixes x 2 pages each
        # default grace period: freshly written orphans are NOT swept (they
        # could be a concurrent persist mid-flight); grace_s=0 reclaims them
        res = st.gc_orphan_blobs()
        assert res["swept"] == 0 and res["recent"] == 4
        res = st.gc_orphan_blobs(grace_s=0.0)
        assert res["swept"] == 4       # the 2 pruned prefixes' pages
        assert res["kept"] == 4
        assert st.stats["gc_swept_blobs"] == 4
        assert self._blob_count(root) == 4
        # surviving manifests re-hydrate from a fresh store on the same root
        fresh = self._mk(root)
        for seed in (2, 3):
            snap, kv = snaps[seed][0], snaps[seed][1]
            entry = fresh.rehydrate_prefix(snap.prompt)
            assert entry is not None
            np.testing.assert_array_equal(entry.pages.leaves()[0], kv)

    def test_live_shared_pages_survive_cross_kernel_sweep(self):
        """Cross-kernel: store A persists a prefix; store B (same root,
        'another process') spills a context whose pages are in NO manifest
        and also holds pages SHARED with A's manifest. B's sweep must keep
        both -- manifest pages by the mark phase, B's spilled pages by the
        in-RAM table -- and reclaim only a genuinely dead blob."""
        root = tempfile.mkdtemp(prefix="kvgc2-")
        a = self._mk(root)
        snap_a, kv_a = self._persist(a, seed=10)
        b = self._mk(root)
        # B shares A's content (same bytes -> same pids) AND has private
        # un-persisted state spilled to disk
        shared = b.put(self.LAY, [kv_a], seq_len=32)
        kv_b = np.zeros((1, 64, 2), np.float32)
        kv_b[0, :16] = np.random.default_rng(11).normal(size=(16, 2))
        private = b.put(self.LAY, [kv_b], seq_len=16)
        assert b.demote_handle(shared) and b.demote_handle(private)
        # a genuinely dead blob: no manifest, no table entry anywhere
        b.storage.kv_page_save("deadbeef", b"orphan")
        res = b.gc_orphan_blobs(grace_s=0.0)
        assert res["swept"] == 1       # only the dead blob
        # B's spilled private state still loads (pages promoted from disk)
        np.testing.assert_array_equal(b.leaves(private)[0], kv_b)
        np.testing.assert_array_equal(b.leaves(shared)[0], kv_a)
        # and a third kernel still re-hydrates A's persisted prefix
        c = self._mk(root)
        entry = c.rehydrate_prefix(snap_a.prompt)
        assert entry is not None
        np.testing.assert_array_equal(entry.pages.leaves()[0], kv_a)


# ---------------------------------------------------------------------------
# control plane on page identity
# ---------------------------------------------------------------------------
class TestFractionalAffinity:
    def _mixed_entry_cache(self):
        """A prefix entry whose pages span two origins: 3 pages computed on
        core 0, the extension's boundary page on core 1 (the harvesting
        engine -- which binary affinity would credit with everything)."""
        st = _store()
        st.register_layout("aff|len64", [1], [(1, 64, 2)], [np.float32])
        rng = np.random.default_rng(3)
        kv = np.zeros((1, 64, 2), np.float32)
        kv[0, :48] = rng.normal(size=(48, 2))
        h0 = st.put("aff|len64", [kv], seq_len=48, origin=0)
        kv2 = kv.copy()
        kv2[0, 48:] = rng.normal(size=(16, 2))
        h1 = st.put("aff|len64", [kv2], seq_len=64, origin=1)
        pc = PrefixCache(page_store=st)
        prompt = np.arange(100, 164)
        snap = SimpleNamespace(prompt=prompt, seq_len=64, pages=h1, origin=1,
                               generated=[], state=None, logits=None,
                               nbytes=lambda: 1024, release=h1.release)
        assert pc.insert(snap)
        h0.release()
        return pc, prompt

    def test_fractional_routing_picks_max_residency_core(self):
        from repro.control.affinity import AffinityRouter
        pc, prompt = self._mixed_entry_cache()
        query = np.concatenate([prompt, np.array([7, 8], np.int32)])
        frac = AffinityRouter(pc, min_tokens=16)
        res = frac.probe(query)
        assert res is not None and res[2] == [0, 0, 0, 1]
        assert res[0] == 0                                  # dominant origin
        assert frac.affinity_pages(0, res, 16) == 3
        assert frac.affinity_pages(1, res, 16) == 1
        assert frac.stats["fractional_probes"] == 1
        # binary router credits the harvesting core with ALL pages -- the
        # misroute fractional scoring exists to fix
        binary = AffinityRouter(pc, min_tokens=16, fractional=False)
        bres = binary.probe(query)
        assert binary.affinity_pages(0, bres, 16) == 0
        assert binary.affinity_pages(1, bres, 16) == 4


class TestMigrationCostModel:
    def test_pick_cheapest_bytes_per_remaining_token(self):
        # same SLO class: 2nd slot has fewer resident bytes per remaining
        # token -> cheaper to move per unit of offloaded work
        cands = [(0, 1, 4096, 4), (1, 1, 2048, 16), (2, 1, 8192, 32)]
        slot, cost = pick_migration_victim(cands)
        assert slot == 1 and cost == migration_cost(2048, 16)
        # SLO class still leads: a best_effort victim beats a cheaper batch
        cands = [(0, 1, 64, 64), (1, 2, 1 << 20, 1)]
        slot, _ = pick_migration_victim(cands)
        assert slot == 1
        # degenerate (recurrent models: resident_bytes == 0) falls back to
        # the longest tail, the pre-cost-model behaviour
        cands = [(0, 1, 0, 4), (1, 1, 0, 40)]
        assert pick_migration_victim(cands)[0] == 1
        assert pick_migration_victim([]) == (None, None)

    def test_engine_resident_bytes(self):
        eng = ServingEngine(TINY, max_slots=2, max_len=128, rng_seed=5)
        assert eng.kv_bytes_per_token > 0
        slot = eng.add_sequence(np.arange(1, 40), max_new=8)
        held = eng.pager.held(f"slot{slot}")
        assert eng.resident_bytes(slot) == held * 16 * eng.kv_bytes_per_token
        _drain(eng, slot)
        assert eng.resident_bytes(slot) == 0


class TestAdmissionController:
    def _miss(self, plane, n):
        for _ in range(n):
            plane.bus.record("slo_miss", 1.0, "interactive")

    def test_sheds_best_effort_under_interactive_misses(self):
        plane = ControlPlane(2, admission_kw={"window": 16, "miss_rate": 0.5,
                                              "min_samples": 4})
        be = LLMSyscall("a", {"prompt": [1, 2], "slo_class": "best_effort"})
        assert not plane.should_shed(be)       # no samples yet
        self._miss(plane, 6)
        assert plane.interactive_miss_rate() == 1.0
        assert plane.should_shed(be)
        ia = LLMSyscall("a", {"prompt": [1, 2], "slo_class": "interactive"})
        ba = LLMSyscall("a", {"prompt": [1, 2], "slo_class": "batch"})
        assert not plane.should_shed(ia)
        assert not plane.should_shed(ba)       # only best_effort sheds
        assert plane.metrics()["admission_shed"] == 1
        off = ControlPlane(2, admission=False)
        self._miss(off, 8)
        be2 = LLMSyscall("a", {"prompt": [1], "slo_class": "best_effort"})
        assert not off.should_shed(be2)

    def test_miss_window_decays_by_time(self):
        """A burst of misses must not latch shedding forever: once no
        interactive syscall has completed for admission_ttl_s, the stale
        samples stop counting."""
        import time as _t
        plane = ControlPlane(2, admission_kw={"min_samples": 4,
                                              "ttl_s": 10.0})
        self._miss(plane, 6)
        plane._last_interactive_activity = _t.monotonic()
        assert plane.interactive_miss_rate() == 1.0
        plane._last_interactive_activity = _t.monotonic() - 60.0  # long idle
        assert plane.interactive_miss_rate() == 0.0
        be = LLMSyscall("a", {"prompt": [1], "slo_class": "best_effort"})
        assert not plane.should_shed(be)
        # starved-but-queued interactive work counts as activity: the
        # controller must not switch off mid-pileup
        q = plane.make_queue()
        q.put(LLMSyscall("a", {"prompt": [1], "slo_class": "interactive"}))
        assert plane.interactive_miss_rate() == 1.0

    def test_scheduler_fails_shed_syscall_fast(self):
        k = AIOSKernel(arch="tiny", scheduler="batched", num_cores=1,
                       control=True,
                       control_kw={"admission_kw": {"min_samples": 4}},
                       engine_kw={"max_slots": 2, "max_len": 64})
        k.start()
        try:
            for _ in range(8):
                k.control.bus.record("slo_miss", 1.0, "interactive")
            sc = LLMSyscall("a", {"prompt": [1, 2, 3], "max_new_tokens": 4,
                                  "slo_class": "best_effort"})
            k.submit(sc)
            with pytest.raises(RuntimeError, match="admission controller"):
                sc.join(timeout=5)
            assert k.metrics()["control"]["admission_shed"] == 1
        finally:
            k.stop()


class TestP90Planning:
    def test_rolling_backlog_series_marks_spiky_core_hot(self):
        bus = TelemetryBus(2)
        reb = Rebalancer(bus, min_gap=2, hysteresis_ticks=1)
        base = dict(free_pages=16, page_size=16, prefill_debt=0,
                    resident_kv_bytes=0, migrations_out=0, migrations_in=0)
        bus.publish(0, free_slots=3, running=1, backlog=0, **base)
        bus.publish(1, free_slots=4, running=0, backlog=0, **base)
        # instantaneous gauges say the gap is 1 < min_gap: no decision
        assert reb.plan(central_backlog=0) is None
        # core 0's backlog SPIKES repeatedly even though the tick catches it
        # drained; the rolling p90 sees through the sampling luck
        for v in (6, 6, 6, 0, 6, 6):
            bus.record("backlog", v, "core0")
        decision = reb.plan(central_backlog=0)
        assert decision is not None
        hot, cold, n = decision
        assert (hot, cold) == (0, 1) and n >= 1
        assert reb.stats["p90_influenced_ticks"] >= 1
