"""Serving-side fault tolerance: multi-core pools, core-fault retry (the
context snapshot bounds lost work to one quantum), straggler-adjacent
behaviour of the pool router."""
import threading
import time

import pytest

from repro.agents import register_builtin_tools
from repro.core import AIOSKernel
from repro.core.llm_core import LLMCorePool
from repro.sdk.query import LLMQuery


def _llm(agent, max_new=6):
    return LLMQuery(prompt=list(range(1, 9)),
                    max_new_tokens=max_new).to_syscall(agent)


def test_multi_core_pool_serves_concurrently():
    k = AIOSKernel(arch="tiny", scheduler="fifo", num_cores=2,
                   engine_kw={"max_slots": 2, "max_len": 128})
    register_builtin_tools(k.tools)
    with k:
        scs = [_llm(f"mc{i}") for i in range(6)]
        for sc in scs:
            k.submit(sc)
        outs = [sc.join(timeout=300) for sc in scs]
    assert all(len(o["tokens"]) == 6 for o in outs)
    # both cores did work
    assert all(c.executed > 0 for c in k.pool.cores)


def test_pool_router_strategies():
    k = AIOSKernel(arch="tiny", scheduler="fifo", num_cores=3,
                   engine_kw={"max_slots": 2, "max_len": 64})
    pool = k.pool
    # round robin cycles
    seq = [pool.route().core_id for _ in range(6)]
    assert sorted(set(seq)) == [0, 1, 2]
    pool.strategy = "sequential"
    assert pool.route().core_id == 0
    k.stop()


def test_core_fault_retries_and_completes():
    """A core that faults once must not fail the syscall: the scheduler
    requeues it and a healthy execution completes it."""
    k = AIOSKernel(arch="tiny", scheduler="rr", quantum=4,
                   engine_kw={"max_slots": 2, "max_len": 128})
    register_builtin_tools(k.tools)
    core = k.pool.cores[0]
    original = core.execute_llm_syscall
    state = {"failed": False}

    def flaky(sc, quantum=None):
        if not state["failed"]:
            state["failed"] = True
            raise RuntimeError("injected core fault")
        return original(sc, quantum=quantum)

    core.execute_llm_syscall = flaky
    with k:
        sc = _llm("faulty", max_new=8)
        k.submit(sc)
        out = sc.join(timeout=300)
    assert out["finished"] and len(out["tokens"]) == 8
    assert getattr(sc, "_retries", 0) == 1


def test_core_fault_exhausts_retries():
    k = AIOSKernel(arch="tiny", scheduler="fifo",
                   engine_kw={"max_slots": 2, "max_len": 128})
    register_builtin_tools(k.tools)
    core = k.pool.cores[0]

    def always_fail(sc, quantum=None):
        raise RuntimeError("dead core")

    core.execute_llm_syscall = always_fail
    with k:
        sc = _llm("doomed")
        k.submit(sc)
        with pytest.raises(RuntimeError, match="dead core"):
            sc.join(timeout=300)
    assert sc._retries == k.scheduler.llm_retries
