"""Hypothesis compatibility layer: the property-based tests degrade to
skipped tests when `hypothesis` is not installed (CI installs it via the
``dev`` extra in pyproject.toml), instead of erroring the whole module at
collection time.

Usage in test modules::

    from _hyp import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for `hypothesis.strategies`: every strategy constructor
        returns an inert placeholder (the decorated test is skipped anyway)."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg stub: the strategy-named parameters must not be
            # mistaken for pytest fixtures
            @pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[dev])")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
