"""Differential serving-equivalence harness.

The serving stack exposes three prefill/decode paths that must emit
bit-identical per-sequence token streams:

  * serial  -- one full single-sequence prefill per XLA call, guarded decode
               dispatch (the pre-PR-2 baseline);
  * chunked -- batched chunked prefill interleaved with the guarded decode
               dispatch (the PR-2..4 path, ``mixed_step=False``);
  * mixed   -- ONE unified dispatch per tick: prefill chunks + decode tokens
               as length-1 chunk rows, inactive rows masked per row (this
               PR's default).

The harness generates random workloads from a pure seed -- admission bursts
of random prompt lengths, eager and non-eager, greedy and temperature
sampling with fixed per-sequence streams, prefix reuse (exact resubmission
and grown-conversation suffix extension) and mid-stream migration to a twin
engine (logits- and text-kind snapshots) -- and replays the SAME schedule
against every {path} x {paged_kv on/off} combination on all four model
archs, asserting token bit-equality.

Every sequence is also admitted with a streaming sink (the per-token
channel behind ``llm_chat(stream=True)``): the harness asserts the streamed
token sequence is bit-equal to the harvested result for every sequence in
every combination -- including across migration, where ``restore`` re-wires
the sink and the pending token must be emitted exactly once.

Deterministic seeds always run; with ``hypothesis`` installed (CI dev
extras) a property sweep explores more seeds. Per-row chunk-mask unit tests
and the VLM mixed-batch coverage live here too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs import get_config
from repro.memory import KVPageStore
from repro.models import build_model
from repro.serving import PrefixCache, ServingEngine

ARCHS = ["tiny", "moonshot-v1-16b-a3b", "rwkv6-1.6b", "recurrentgemma-2b"]
MODES = ["serial", "chunked", "mixed"]
MAX_LEN = 96
SLOTS = 4
TICK_LIMIT = 4000        # safety net: a diverging while-loop fails, not hangs


def _cfg(arch):
    return get_config(arch) if arch == "tiny" else get_config(arch, smoke=True)


_PARAMS = {}


def _params(arch):
    if arch not in _PARAMS:
        model = build_model(_cfg(arch))
        _PARAMS[arch], _ = model.init_params(jax.random.key(0))
    return _PARAMS[arch]


# ---------------------------------------------------------------------------
# schedule generation (pure function of the seed -- no engine state leaks in)
# ---------------------------------------------------------------------------

def _make_schedule(seed, n_events=9):
    rng = np.random.default_rng(seed)
    temperature = float(rng.choice([0.0, 0.7]))
    events = []
    n_seqs = 0
    for _ in range(n_events):
        r = rng.random()
        if r < 0.45 or n_seqs == 0:
            k = int(rng.integers(1, 3))
            reqs = []
            for _ in range(k):
                u = rng.random()
                if u < 0.2 and n_seqs > 0:
                    reqs.append(("exact", int(rng.integers(0, n_seqs))))
                elif u < 0.45 and n_seqs > 0:
                    suffix = rng.integers(1, 200,
                                          int(rng.integers(2, 12)))
                    reqs.append(("grown", int(rng.integers(0, n_seqs)),
                                 suffix.astype(np.int32)))
                else:
                    toks = rng.integers(1, 200, int(rng.integers(3, 44)))
                    reqs.append(("fresh", toks.astype(np.int32)))
                n_seqs += 1
            events.append(("admit", reqs, bool(rng.integers(2)),
                           int(rng.integers(2, 9))))
        elif r < 0.85:
            events.append(("tick", int(rng.integers(1, 5))))
        else:
            events.append(("migrate", int(rng.integers(0, 10 ** 6)),
                           str(rng.choice(["logits", "text"]))))
    return temperature, events


# ---------------------------------------------------------------------------
# schedule interpreter
# ---------------------------------------------------------------------------

class _Run:
    """Replay one schedule on one (arch, mode, paged) engine pair.
    ``packed`` steers the token-packed ragged dispatch (None = engine
    default: on for non-serial modes); ``kv_quant`` sets the page store's
    off-device precision tier."""

    def __init__(self, arch, mode, paged, temperature, packed=None,
                 kv_quant="off", spec=False, spec_k=4):
        cfg = _cfg(arch)
        self.store = KVPageStore(page_size=16, device_pages=8192,
                                 kv_quant=kv_quant) \
            if paged else None
        self.pc = PrefixCache()
        kw = dict(max_slots=SLOTS, max_len=MAX_LEN, rng_seed=0,
                  temperature=temperature, params=_params(arch),
                  prefix_cache=self.pc, page_store=self.store,
                  serial_prefill=(mode == "serial"),
                  mixed_step=(False if mode == "chunked" else None),
                  packed_step=packed, spec_decode=spec, spec_k=spec_k)
        self.main = ServingEngine(cfg, engine_id=0, **kw)
        self.twin = ServingEngine(cfg, engine_id=1, **kw)
        self.live = {}       # name -> [engine, slot]
        self.streamed = {}   # name -> tokens seen by the streaming sink
        self.finished = {}   # name -> (prompt ints, token list)
        self.max_new = {}    # name -> max_new
        self.names = []      # admission order
        self.ticks = 0

    def _reap(self):
        for name in list(self.live):
            eng, slot = self.live[name]
            if not eng.is_prefilling(slot) and eng.is_done(slot):
                eng.harvest_prefix(slot)
                toks = eng.result(slot)
                eng.free(slot)
                prompt = self._prompts[name]
                self.finished[name] = (prompt, toks)
                del self.live[name]

    def tick(self):
        self.ticks += 1
        if self.ticks > TICK_LIMIT:
            raise AssertionError("schedule did not converge (tick limit)")
        self.main.serve_step()
        self.twin.serve_step()
        self._reap()

    def _drain_seq(self, name):
        while name in self.live:
            self.tick()

    def _resolve_prompt(self, spec):
        kind = spec[0]
        if kind == "fresh":
            return spec[1]
        ref = self.names[spec[1]]
        self._drain_seq(ref)
        prompt, toks = self.finished[ref]
        if kind == "exact":
            return prompt
        grown = np.concatenate(
            [prompt, np.asarray(toks, np.int32), spec[2]])
        return grown[:MAX_LEN - 16]       # keep prompt+max_new admissible

    def run(self, events):
        self._prompts = {}
        for ev in events:
            if ev[0] == "admit":
                _, reqs, eager, max_new = ev
                prompts = [self._resolve_prompt(spec) for spec in reqs]
                while self.main.free_slot_count() < len(prompts):
                    self.tick()
                names = [f"s{len(self.names) + i}"
                         for i in range(len(prompts))]
                sinks = [self.streamed.setdefault(n, []).append
                         for n in names]
                slots = self.main.add_sequences(
                    [dict(prompt=p, max_new=max_new, sink=sink)
                     for p, sink in zip(prompts, sinks)],
                    eager=eager)
                for name, p, slot in zip(names, prompts, slots):
                    self.names.append(name)
                    self._prompts[name] = np.asarray(p, np.int32)
                    self.live[name] = [self.main, slot]
                    self.max_new[name] = max_new
            elif ev[0] == "tick":
                for _ in range(ev[1]):
                    self.tick()
            elif ev[0] == "migrate":
                if not self.live:
                    continue
                name = sorted(self.live)[ev[1] % len(self.live)]
                eng, slot = self.live[name]
                while eng.is_prefilling(slot):
                    self.tick()
                    if name not in self.live:
                        break
                if name not in self.live:
                    continue
                eng, slot = self.live[name]
                snap = eng.snapshot(slot, kind=ev[2])
                other = self.twin if eng is self.main else self.main
                del self.live[name]
                while other.free_slot_count() == 0:
                    self.tick()
                slot2 = other.restore(snap,
                                      sink=self.streamed[name].append)
                snap.release()
                self.live[name] = [other, slot2]
        while self.live:
            self.tick()
        # streaming channel is bit-equal to the harvested result for every
        # sequence -- exactly-once across suspend/migration included
        for name, (_, toks) in self.finished.items():
            assert self.streamed[name] == list(toks), (name, "stream")
        return {name: list(toks) for name, (_, toks) in
                self.finished.items()}


def _assert_equivalent(arch, seed):
    temperature, events = _make_schedule(seed)
    results = {}
    for paged in (False, True):
        for mode in MODES:
            run = _Run(arch, mode, paged, temperature)
            results[(mode, paged)] = run.run(events)
            if mode == "mixed":
                assert run.main.stats["mixed_steps"] > 0
    ref = results[("serial", False)]
    assert any(len(t) > 0 for t in ref.values())
    for key, got in results.items():
        assert got == ref, (arch, seed, temperature, key)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("seed", [0, 1])
def test_equivalence_deterministic(arch, seed):
    """{serial, chunked, mixed} x {paged on/off} emit identical streams on a
    fixed random workload (burst sizes, prompt lengths, temperature, prefix
    reuse, mid-stream migration all drawn from the seed)."""
    _assert_equivalent(arch, seed)


@settings(max_examples=3, deadline=None)
@given(arch=st.sampled_from(ARCHS), seed=st.integers(0, 10 ** 6))
def test_equivalence_property(arch, seed):
    """Property sweep over random workloads (CI: hypothesis installed)."""
    _assert_equivalent(arch, seed)


# ---------------------------------------------------------------------------
# new grid axes: {packed on/off} x {kv_quant off/int8}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_packed_dispatch_token_bit_exact(arch):
    """The token-packed ragged dispatch is a pure LAYOUT change: identical
    token streams to the padded [kb, C] dispatch on the same schedule, and
    the packed path actually fires (decode rows cost 1 token, tail chunks
    their true length)."""
    temperature, events = _make_schedule(5)   # admit-heavy: co-batched chunks
    ref = _Run(arch, "mixed", True, temperature, packed=False).run(events)
    run = _Run(arch, "mixed", True, temperature, packed=True)
    got = run.run(events)
    assert got == ref, arch
    assert run.main.stats["packed_dispatches"] > 0
    assert run.main.stats["packed_tokens"] < \
        run.main.stats["packed_padded_tokens"]


@pytest.mark.parametrize("arch", ARCHS)
def test_kv_quant_int8_greedy_token_exact(arch):
    """int8 page tiers under a greedy schedule WITH migration (snapshots
    land on the host tier, i.e. quantized): token streams stay equal to the
    fp store; kv_quant=off stays bit-exact by construction. Archs with no
    full-width KV leaves (pure-recurrent) drop the page store and pass
    trivially."""
    rng = np.random.default_rng(42)
    p1 = rng.integers(1, 200, 20).astype(np.int32)
    p2 = rng.integers(1, 200, 7).astype(np.int32)
    # migrations AFTER decode ticks: the snapshot covers generated tokens
    # beyond the cached prefix, so its boundary pages are new content that
    # lands (quantized) on the host tier instead of deduping onto the
    # device-resident prefix pages
    events = [
        ("admit", [("fresh", p1), ("fresh", p2)], True, 12),
        ("tick", 6),
        ("migrate", 0, "logits"),
        ("tick", 3),
        ("migrate", 0, "logits"),
        ("admit", [("exact", 0)], True, 6),
    ]
    ref = _Run(arch, "mixed", True, 0.0, kv_quant="off").run(events)
    run = _Run(arch, "mixed", True, 0.0, kv_quant="int8")
    got = run.run(events)
    assert got == ref, arch
    if run.main.page_store is not None:
        assert run.store.stats["quantized_pages"] > 0


@pytest.mark.parametrize("arch", ["tiny", "moonshot-v1-16b-a3b"])
def test_kv_quant_exactness_delta_report(arch):
    """Quantified exactness of one int8 suspend/resume round-trip: greedy
    next-token equality, with the logit max-abs-err printed (the harness's
    exactness report) and bounded."""
    cfg = _cfg(arch)

    def _roundtrip(kv_quant):
        store = KVPageStore(page_size=16, device_pages=8192,
                            kv_quant=kv_quant)
        eng = ServingEngine(cfg, max_slots=2, max_len=MAX_LEN, rng_seed=0,
                            params=_params(arch), page_store=store)
        rng = np.random.default_rng(2)
        prompt = rng.integers(1, 200, 24).astype(np.int32)
        slot = eng.add_sequence(prompt, max_new=16)
        for _ in range(4):
            eng.serve_step()
        snap = eng.snapshot(slot, kind="logits")   # put -> host tier
        eng.free(slot)
        slot2 = eng.restore(snap)
        snap.release()
        while not eng.is_done(slot2):
            eng.serve_step()
        toks = eng.result(slot2)
        return store, toks, np.asarray(eng._last_logits[slot2], np.float64)

    store_fp, toks_fp, logits_fp = _roundtrip("off")
    store_q, toks_q, logits_q = _roundtrip("int8")
    assert store_fp.stats["quantized_pages"] == 0
    assert store_q.stats["quantized_pages"] > 0
    delta = float(np.max(np.abs(logits_fp - logits_q)))
    print(f"\n[kv_quant=int8] {arch}: greedy tokens equal="
          f"{toks_fp == toks_q} logit max-abs-err={delta:.3e} "
          f"saved={store_q.stats['quant_saved_bytes']}B")
    assert toks_fp == toks_q, arch     # greedy token equality
    assert delta < 0.5, delta          # bounded logit drift


# ---------------------------------------------------------------------------
# speculative decoding: greedy bit-equality, arch gating, acceptance law
# ---------------------------------------------------------------------------

SPEC_ARCHS = ["tiny", "moonshot-v1-16b-a3b"]          # causal transformers
SPEC_GATED = ["rwkv6-1.6b", "recurrentgemma-2b"]      # stateful: no rollback


def _spec_schedule(seed):
    """Repetitive agent-style traffic -- templated prompts built from a
    small motif pool (the n-gram drafter's bread and butter) -- with prefix
    reuse and mid-stream migration (both snapshot kinds) in the mix, so a
    pending rejected-draft residual token crosses an engine boundary."""
    rng = np.random.default_rng(seed)
    motifs = [rng.integers(1, 200, 4).astype(np.int32) for _ in range(3)]

    def prompt():
        parts = [motifs[int(rng.integers(0, len(motifs)))]
                 for _ in range(int(rng.integers(3, 9)))]
        return np.concatenate(parts)[:44]

    return [
        ("admit", [("fresh", prompt()), ("fresh", prompt())], False, 12),
        ("tick", 3),
        ("migrate", int(rng.integers(0, 10 ** 6)), "text"),
        ("admit", [("grown", 0, prompt()[:8]), ("fresh", prompt())],
         True, 10),
        ("tick", 2),
        ("migrate", int(rng.integers(0, 10 ** 6)), "logits"),
        ("admit", [("exact", 1)], False, 8),
    ]


@pytest.mark.parametrize("arch", SPEC_ARCHS)
@pytest.mark.parametrize("seed", [0, 3])
def test_spec_decode_greedy_bit_exact(arch, seed):
    """spec_decode on/off is invisible in the greedy token stream: the
    drafter only proposes what argmax verification would emit anyway, and
    rejected drafts roll back without a trace -- across chunked prefill
    co-batching, prefix reuse and mid-stream migration. The spec path must
    actually fire AND accept (repetitive traffic guarantees drafts)."""
    events = _spec_schedule(seed)
    ref = _Run(arch, "mixed", True, 0.0).run(events)
    run = _Run(arch, "mixed", True, 0.0, spec=True)
    got = run.run(events)
    assert got == ref, (arch, seed)
    stats = run.main.stats
    assert stats["spec_dispatches"] > 0, (arch, seed)
    assert stats["spec_accepted_tokens"] > 0, (arch, seed)


@pytest.mark.parametrize("arch", SPEC_GATED)
def test_spec_decode_gates_stateful_archs(arch):
    """Stateful archs (in-place recurrent carries / rolling windows) cannot
    rewind to a rejected position: spec_decode=True must silently gate off
    and leave the stream untouched."""
    events = _spec_schedule(0)
    ref = _Run(arch, "mixed", True, 0.0).run(events)
    run = _Run(arch, "mixed", True, 0.0, spec=True)
    got = run.run(events)
    assert run.main.spec is False
    assert run.main.stats["spec_dispatches"] == 0
    assert got == ref, arch


def test_spec_decode_temperature_stream_integrity():
    """Temperature spec streams are distribution-identical, not bitwise
    (acceptance substitutes drafted tokens for fresh draws), so the
    engine-level claim is integrity: the schedule converges, every sequence
    emits, and the streaming channel equals the harvested result token for
    token (asserted inside _Run.run) -- across migration with a pending
    residual-corrected token."""
    events = _spec_schedule(1)
    run = _Run("tiny", "mixed", True, 0.7, spec=True)
    out = run.run(events)
    assert all(len(t) > 0 for t in out.values())


def test_spec_decode_eos_in_draft_stops_exactly():
    """A drafted EOS may commit (it truncates the draft at that point);
    the stream must stop exactly where the non-spec stream stops."""
    cfg = _cfg("tiny")
    pat = np.asarray([5, 9, 13, 7] * 10, np.int32)

    def run(spec):
        eng = ServingEngine(cfg, max_slots=2, max_len=128, rng_seed=0,
                            params=_params("tiny"), spec_decode=spec)
        # eos = the token greedy decoding emits -> stops after 1 token; and
        # a non-eos run bounded by max_new exercises the max_new clamp
        slot = eng.add_sequence(pat, max_new=16, eos_id=283)
        ticks = 0
        while not eng.is_done(slot):
            eng.serve_step()
            ticks += 1
            assert ticks < 200
        out = eng.result(slot)
        eng.free(slot)
        slot = eng.add_sequence(pat[:-1], max_new=5)
        while not eng.is_done(slot):
            eng.serve_step()
        out2 = eng.result(slot)
        eng.free(slot)
        return out, out2

    off, off2 = run(False)
    on, on2 = run(True)
    assert on == off
    assert on2 == off2 and len(on2) == 5


class TestSpecVerifySampler:
    """Unit level for ``sampler.spec_verify``: the speculative-sampling
    acceptance rule for point-mass (self-drafted) proposals."""

    V = 13

    @staticmethod
    def _keys(n, base=10):
        return jax.vmap(jax.random.key)(
            jnp.arange(base, base + n, dtype=jnp.uint32))

    def test_no_draft_reduces_to_sample_bitwise(self):
        """m = 0 rows (both Cs == 1 and padded draft columns) emit EXACTLY
        ``sample``'s draw at the same counter -- the bitwise anchor that
        makes a spec tick with empty drafts a plain decode tick."""
        from repro.serving import sampler as smp
        rng = np.random.default_rng(0)
        R = 6
        keys = self._keys(R)
        counters = jnp.asarray(rng.integers(0, 50, R), jnp.int32)
        for Cs in (1, 4):
            logits = jnp.asarray(rng.normal(size=(R, Cs, self.V)) * 2.0,
                                 jnp.float32)
            n_acc, pend = smp.spec_verify(
                logits, jnp.zeros((R, Cs - 1), jnp.int32),
                jnp.zeros((R,), jnp.int32), keys, counters, temperature=0.7)
            ref = smp.sample(logits[:, 0], keys, counters, temperature=0.7)
            assert np.array_equal(np.asarray(pend), np.asarray(ref)), Cs
            assert np.all(np.asarray(n_acc) == 0)

    def test_all_accept_bonus_is_samples_draw_bitwise(self):
        """With every draft accepted, the bonus draw uses the UNsalted key
        at counter c0+m -- bitwise the token a non-speculative stream would
        sample there (the property that keeps an all-accept spec stream on
        the non-spec stream's random trajectory)."""
        from repro.serving import sampler as smp
        rng = np.random.default_rng(1)
        R, m = 4, 3
        draft = rng.integers(0, self.V, (R, m)).astype(np.int32)
        logits = np.full((R, m + 1, self.V), -20.0, np.float32)
        for r in range(R):
            for i in range(m):
                logits[r, i, draft[r, i]] = 20.0   # p(d) ~ 1: always accept
        logits[:, m] = rng.normal(size=(R, self.V)).astype(np.float32)
        keys = self._keys(R, base=77)
        counters = jnp.asarray(rng.integers(0, 9, R), jnp.int32)
        n_acc, pend = smp.spec_verify(
            jnp.asarray(logits), jnp.asarray(draft),
            jnp.full((R,), m, jnp.int32), keys, counters, temperature=0.7)
        assert np.all(np.asarray(n_acc) == m)
        ref = smp.sample(jnp.asarray(logits[:, m]), keys, counters + m,
                         temperature=0.7)
        assert np.array_equal(np.asarray(pend), np.asarray(ref))

    def test_first_position_marginal_is_distribution_identical(self):
        """Empirical law of the first post-pending token (drafted token if
        accepted, residual resample otherwise) over many independent keys
        == softmax(logits/T): the speculative-sampling correctness
        guarantee, measured."""
        from repro.serving import sampler as smp
        V, T, d0, N = 5, 0.7, 3, 4096
        vec = np.array([1.0, 0.3, -0.5, 2.0, 0.0], np.float32)
        keys = self._keys(N, base=1000)
        logits = jnp.broadcast_to(jnp.asarray(vec), (N, 2, V))
        n_acc, pend = smp.spec_verify(
            logits, jnp.full((N, 1), d0, jnp.int32),
            jnp.ones((N,), jnp.int32), keys,
            jnp.zeros((N,), jnp.int32), temperature=T)
        tok = np.where(np.asarray(n_acc) >= 1, d0, np.asarray(pend))
        p = np.exp(vec / T)
        p /= p.sum()
        freq = np.bincount(tok, minlength=V) / N
        assert float(np.max(np.abs(freq - p))) < 0.03, (freq, p)
        # and acceptance is doing real work: d0 accepted ~ p(d0) of the time
        acc_rate = float(np.mean(np.asarray(n_acc) >= 1))
        assert abs(acc_rate - float(p[d0])) < 0.03

    def test_greedy_prefix_rule(self):
        """Greedy acceptance = longest exact argmax prefix; the pending is
        the argmax AFTER the last accepted position."""
        from repro.serving import sampler as smp
        rng = np.random.default_rng(3)
        logits = rng.normal(size=(1, 4, self.V)).astype(np.float32)
        am = np.argmax(logits[0], -1)
        draft = np.array([[am[0], (am[1] + 1) % self.V, am[2]]], np.int32)
        n_acc, pend = smp.spec_verify(
            jnp.asarray(logits), jnp.asarray(draft),
            jnp.full((1,), 3, jnp.int32), self._keys(1),
            jnp.zeros((1,), jnp.int32), temperature=0.0)
        assert int(n_acc[0]) == 1          # d1 matches, d2 mismatches
        assert int(pend[0]) == am[1]       # argmax at the first mismatch


# ---------------------------------------------------------------------------
# per-row chunk-mask unit level (the generalized no-op invariant)
# ---------------------------------------------------------------------------

def _batch_axes(model):
    _, logical = model.init_cache(1, 8)

    def _is_label(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
    labels = jax.tree.leaves(logical, is_leaf=_is_label)
    return [lab.index("batch") if "batch" in lab else None for lab in labels]


def _rows(cache, axes, rows):
    out = []
    for leaf, ax in zip(jax.tree.leaves(cache), axes):
        leaf = np.asarray(leaf)
        out.append(leaf if ax is None else np.take(leaf, rows, axis=ax))
    return out


def _assert_rows_equal(a, b, axes, rows, ctx):
    for i, (x, y) in enumerate(zip(_rows(a, axes, rows),
                                   _rows(b, axes, rows))):
        assert np.array_equal(x, y), (ctx, f"leaf {i}")


class TestPerRowChunkMask:
    """One chunk dispatch with lengths [C, 1, 0]: the prefill row consumes
    its chunk, the decode row is bit-identical to decode_step, and the
    inactive row's every cache leaf is preserved bit-for-bit -- the per-row
    mask that replaced the decode keep-guard."""

    def _setup(self, arch, B=3, P=13):
        cfg = _cfg(arch)
        model = build_model(cfg)
        params = _params(arch)
        cache, _ = model.init_cache(B, 64)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(1, 200, (B, P)), jnp.int32)
        cache, logits = model.prefill(params, toks, cache,
                                      lengths=jnp.full((B,), P, jnp.int32))
        return cfg, model, params, cache, logits, P

    @pytest.mark.parametrize("arch", ARCHS)
    def test_mixed_row_lengths(self, arch):
        cfg, model, params, cache, logits, P = self._setup(arch)
        axes = _batch_axes(model)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        cache_dec, logits_dec = model.decode_step(params, nxt, cache)

        C = 8
        rng = np.random.default_rng(1)
        buf = np.zeros((3, C), np.int32)
        buf[0] = rng.integers(1, 200, C)          # row 0: prefill C more
        buf[1, 0] = int(nxt[1])                   # row 1: decode
        lengths = np.array([C, 1, 0], np.int32)   # row 2: inactive
        offs = np.array([P, P, 0], np.int32)
        cache_mix, logits_mix = model.prefill_chunk(
            params, jnp.asarray(buf), cache, q_offset=jnp.asarray(offs),
            lengths=jnp.asarray(lengths), kv_width=None)

        # decode row: logits and every cache leaf bitwise == decode_step
        assert np.array_equal(np.asarray(logits_mix)[1],
                              np.asarray(logits_dec)[1])
        _assert_rows_equal(cache_mix, cache_dec, axes, [1],
                           (arch, "decode row"))
        # inactive row: strict no-op
        _assert_rows_equal(cache_mix, cache, axes, [2],
                           (arch, "inactive row"))
        # prefill row: independent of batch composition (same chunk alone)
        cache_solo, logits_solo = model.prefill_chunk(
            params, jnp.asarray(buf), cache, q_offset=jnp.asarray(offs),
            lengths=jnp.asarray(np.array([C, 0, 0], np.int32)),
            kv_width=None)
        assert np.array_equal(np.asarray(logits_mix)[0],
                              np.asarray(logits_solo)[0])
        _assert_rows_equal(cache_mix, cache_solo, axes, [0],
                           (arch, "prefill row"))

    @pytest.mark.parametrize("arch", ["recurrentgemma-2b", "rwkv6-1.6b"])
    def test_wrap_around_rows_track_decode_step(self, arch):
        """Rolling-buffer writes wrap modulo the window and recurrent
        carries evolve every step -- the per-model-leaf masking must keep
        length-1 chunk rows bitwise equal to decode_step across MULTIPLE
        wraps (recurrentgemma smoke window = 16, run ~2.5 windows)."""
        cfg, model, params, cache, logits, P = self._setup(arch)
        axes = _batch_axes(model)
        cache_chunk = cache
        logits_chunk = logits
        for step in range(40):
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            cache, logits = model.decode_step(params, nxt, cache)
            nxt_c = jnp.argmax(logits_chunk, -1).astype(jnp.int32)
            assert np.array_equal(np.asarray(nxt), np.asarray(nxt_c)), step
            cache_chunk, logits_chunk = model.prefill_chunk(
                params, nxt_c[:, None], cache_chunk,
                q_offset=jnp.asarray(np.full((3,), P + step, np.int32)),
                lengths=jnp.ones((3,), jnp.int32), kv_width=None)
            assert np.array_equal(np.asarray(logits),
                                  np.asarray(logits_chunk)), step
            _assert_rows_equal(cache, cache_chunk, axes, [0, 1, 2],
                               (arch, f"step {step}"))


# ---------------------------------------------------------------------------
# packed-layout edge rows (model level)
# ---------------------------------------------------------------------------

def _pack(buf, lens, align=1):
    """Pack the live tokens of a padded [B, C] buffer onto one flat axis,
    rounding each row segment up to ``align`` (the kernel path's block_q)."""
    starts = np.zeros(len(lens), np.int32)
    cur = 0
    for b, n in enumerate(lens):
        starts[b] = cur
        cur += -(-int(n) // align) * align
    flat = np.zeros(max(cur, 1), np.int32)
    for b, n in enumerate(lens):
        flat[starts[b]:starts[b] + int(n)] = buf[b, :int(n)]
    return flat, starts


class TestPackedLayout:
    """``prefill_packed`` is BITWISE ``prefill_chunk`` on the same rows:
    logits of every live row and every cache leaf. Covers the edge rows the
    ragged layout introduces -- length-0 inactive rows, C==1 pure-decode
    rows, short tail chunks, alignment gaps -- and the narrow-chunk window
    wraparound of the rolling-buffer/recurrent models."""

    def _compare(self, arch, lens_list, C, align=1):
        cfg = _cfg(arch)
        model = build_model(cfg)
        params = _params(arch)
        B = len(lens_list)
        cache, _ = model.init_cache(B, MAX_LEN)
        rng = np.random.default_rng(7)
        # distinct per-row offsets: each row continues a short prefix
        pre = np.array([5, 3, 9, 1, 2, 6, 4, 8][:B], np.int32)
        buf0 = np.zeros((B, 16), np.int32)
        for b in range(B):
            buf0[b, :pre[b]] = rng.integers(1, 200, pre[b])
        cache, _ = model.prefill_chunk(
            params, jnp.asarray(buf0), cache,
            q_offset=jnp.zeros((B,), jnp.int32),
            lengths=jnp.asarray(pre), kv_width=None)
        lens = np.asarray(lens_list, np.int32)
        buf = np.zeros((B, C), np.int32)
        for b in range(B):
            buf[b, :lens[b]] = rng.integers(1, 200, lens[b])
        pad_cache, pad_logits = model.prefill_chunk(
            params, jnp.asarray(buf), cache, q_offset=jnp.asarray(pre),
            lengths=jnp.asarray(lens), kv_width=None)
        flat, starts = _pack(buf, lens, align=align)
        pk_cache, pk_logits = model.prefill_packed(
            params, jnp.asarray(flat), cache,
            row_starts=jnp.asarray(starts), q_offset=jnp.asarray(pre),
            lengths=jnp.asarray(lens), chunk=C, kv_width=None)
        for b in range(B):
            if lens[b]:
                assert np.array_equal(np.asarray(pad_logits)[b],
                                      np.asarray(pk_logits)[b]), (arch, b)
        for i, (x, y) in enumerate(zip(jax.tree.leaves(pad_cache),
                                       jax.tree.leaves(pk_cache))):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (arch, i)

    @pytest.mark.parametrize("arch", ARCHS)
    def test_edge_rows_bitwise(self, arch):
        # full chunk, decode row, inactive row, short tail -- one dispatch
        self._compare(arch, [32, 1, 0, 7], C=32)

    @pytest.mark.parametrize("arch", ARCHS)
    def test_pure_decode_c1(self, arch):
        # every live row is a length-1 decode row at chunk=1 (with one
        # inactive row): the densest packing the engine emits
        self._compare(arch, [1, 1, 0, 1], C=1)

    @pytest.mark.parametrize("arch", ARCHS)
    def test_aligned_packing_gaps(self, arch):
        # block_q-aligned segments leave pad gaps between rows: the
        # row_starts-based row derivation must kill the gap tokens
        self._compare(arch, [7, 1, 0, 3], C=8, align=8)

    @pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-2b"])
    def test_narrow_chunk_wraparound(self, arch):
        """Rolling buffers wrap modulo the window and recurrent carries
        evolve every step: repeated narrow packed steps must stay bitwise
        equal to the padded chunk path across multiple wraps
        (recurrentgemma smoke window = 16, run ~2.5 windows)."""
        cfg = _cfg(arch)
        model = build_model(cfg)
        params = _params(arch)
        B, P = 3, 13
        cache, _ = model.init_cache(B, MAX_LEN)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(1, 200, (B, P)), jnp.int32)
        cache, logits = model.prefill(params, toks, cache,
                                      lengths=jnp.full((B,), P, jnp.int32))
        pk_cache, pk_logits = cache, logits
        for step in range(40):
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            offs = jnp.asarray(np.full((B,), P + step, np.int32))
            ones = jnp.ones((B,), jnp.int32)
            cache, logits = model.prefill_chunk(
                params, nxt[:, None], cache, q_offset=offs, lengths=ones,
                kv_width=None)
            nxt_p = jnp.argmax(pk_logits, -1).astype(jnp.int32)
            assert np.array_equal(np.asarray(nxt), np.asarray(nxt_p)), step
            pk_cache, pk_logits = model.prefill_packed(
                params, nxt_p, pk_cache,
                row_starts=jnp.asarray(np.arange(B, dtype=np.int32)),
                q_offset=offs, lengths=ones, chunk=1, kv_width=None)
            assert np.array_equal(np.asarray(logits),
                                  np.asarray(pk_logits)), step
            for i, (x, y) in enumerate(zip(jax.tree.leaves(cache),
                                           jax.tree.leaves(pk_cache))):
                assert np.array_equal(np.asarray(x), np.asarray(y)), \
                    (step, i)


# ---------------------------------------------------------------------------
# VLM mixed-batch coverage
# ---------------------------------------------------------------------------

class TestVLMMixedBatch:
    """Image prompts ride in the same chunk batches as text prompts and
    decoding slots (stacked image_embeds + per-row mask), token-identical
    to the serial one-prompt-per-dispatch path."""

    ARCH = "llama-3.2-vision-90b"

    def _engines(self):
        cfg = _cfg(self.ARCH)
        serial = ServingEngine(cfg, max_slots=SLOTS, max_len=MAX_LEN,
                               rng_seed=0, params=_params(self.ARCH),
                               serial_prefill=True)
        mixed = ServingEngine(cfg, max_slots=SLOTS, max_len=MAX_LEN,
                              rng_seed=0, params=_params(self.ARCH))
        return cfg, serial, mixed

    @staticmethod
    def _drain(eng, slots):
        outs = {}
        while len(outs) < len(slots):
            for s in slots:
                if s not in outs and eng.is_done(s):
                    outs[s] = eng.result(s)
                    eng.free(s)
            if len(outs) < len(slots):
                eng.serve_step()
        return [outs[s] for s in slots]

    def test_image_and_text_burst_matches_serial(self):
        cfg, serial, mixed = self._engines()
        rng = np.random.default_rng(5)
        prompts = [rng.integers(1, cfg.vocab - 1, n).astype(np.int32)
                   for n in (12, 30, 21)]
        img = [jax.random.normal(
            jax.random.key(9 + i),
            (1, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
            for i in range(2)]
        reqs = [dict(prompt=prompts[0], max_new=8, image_embeds=img[0]),
                dict(prompt=prompts[1], max_new=8),          # text-only
                dict(prompt=prompts[2], max_new=8, image_embeds=img[1])]
        ref = [self._drain(serial, [serial.add_sequence(**r)])[0]
               for r in reqs]

        # a runner decodes while the image+text burst admits: every tick is
        # one dispatch carrying image rows, a text row and the decode row
        runner_prompt = rng.integers(1, cfg.vocab - 1, 9).astype(np.int32)
        runner_ref = self._drain(
            serial, [serial.add_sequence(runner_prompt, max_new=12)])[0]
        runner = mixed.add_sequence(runner_prompt, max_new=12)
        mixed.serve_step()
        slots = mixed.add_sequences([dict(**r) for r in reqs], eager=False)
        outs = self._drain(mixed, slots + [runner])
        assert outs[:3] == ref
        assert outs[3] == runner_ref
        assert mixed.stats["mixed_steps"] > 0

    def test_image_burst_packed_matches_padded_and_fires(self):
        """Image rows join the token-packed ragged dispatch (their TEXT
        tokens pack onto the flat axis; frontend embeddings stay per-row
        dense -- padded-within-packed): token streams must equal the padded
        image dispatch, and the packed image program must actually run."""
        cfg = _cfg(self.ARCH)
        kw = dict(max_slots=SLOTS, max_len=MAX_LEN, rng_seed=0,
                  params=_params(self.ARCH))
        pad = ServingEngine(cfg, packed_step=False, **kw)
        pk = ServingEngine(cfg, packed_step=True, **kw)
        calls = []
        orig = pk._prefill_packed_img_jit

        def spy(*a, **k):
            calls.append(1)
            return orig(*a, **k)

        pk._prefill_packed_img_jit = spy
        rng = np.random.default_rng(5)
        prompts = [rng.integers(1, cfg.vocab - 1, n).astype(np.int32)
                   for n in (12, 30, 21)]
        img = [jax.random.normal(
            jax.random.key(9 + i),
            (1, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
            for i in range(2)]
        reqs = [dict(prompt=prompts[0], max_new=8, image_embeds=img[0]),
                dict(prompt=prompts[1], max_new=8),
                dict(prompt=prompts[2], max_new=8, image_embeds=img[1])]
        runner_prompt = rng.integers(1, cfg.vocab - 1, 9).astype(np.int32)
        outs = {}
        for eng in (pad, pk):
            runner = eng.add_sequence(runner_prompt, max_new=12)
            eng.serve_step()
            slots = eng.add_sequences([dict(**r) for r in reqs],
                                      eager=False)
            outs[eng] = self._drain(eng, slots + [runner])
        assert outs[pk] == outs[pad]
        assert calls, "packed image dispatch never fired"
        assert pk.stats["packed_dispatches"] > 0

    def test_text_prompt_after_image_slot_is_clean(self):
        """A text prompt reusing a slot that held an image conversation must
        see pristine (zero) frontend K/V, not the previous occupant's."""
        cfg, serial, mixed = self._engines()
        rng = np.random.default_rng(6)
        text = rng.integers(1, cfg.vocab - 1, 18).astype(np.int32)
        ref = self._drain(serial, [serial.add_sequence(text, max_new=6)])[0]
        img = jax.random.normal(
            jax.random.key(3), (1, cfg.num_frontend_tokens, cfg.d_model),
            jnp.bfloat16)
        dirty = mixed.add_sequence(
            rng.integers(1, cfg.vocab - 1, 10).astype(np.int32),
            max_new=4, image_embeds=img, eager=False)
        self._drain(mixed, [dirty])
        slot = mixed.add_sequence(text, max_new=6, eager=False)
        got = self._drain(mixed, [slot])[0]
        assert got == ref
